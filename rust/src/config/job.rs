//! The FLsim job configuration.
//!
//! Mirrors the paper's Figure 2 sections: (a) dataset parameters,
//! (b) consensus configuration, (c) topology/cluster configuration,
//! (d) FL strategy configuration (with training + aggregation
//! hyper-parameters), (e/f) node defaults & overrides. Configs load from the
//! YAML subset in [`crate::util::yaml`] (anchors/merge keys included) or are
//! built programmatically via the preset constructors.

use anyhow::{anyhow, bail, Result};

use crate::aggregate::mean::ReductionOrder;
use crate::config::adversary::{AdversaryConfig, FaultsConfig, RobustAggConfig};
use crate::config::channel::ChannelConfig;
use crate::data::dataset::{DatasetSpec, Distribution};
use crate::kvstore::netsim::{LinkModel, LinkPolicy};
use crate::strategy::StrategyKind;
use crate::topology::TopologyKind;
use crate::util::json::Json;
use crate::util::yaml::Yaml;

/// Training hyper-parameters (paper Fig 2d `train_params`).
#[derive(Clone, Debug, PartialEq)]
pub struct TrainParams {
    pub learning_rate: f32,
    pub local_epochs: usize,
}

impl Default for TrainParams {
    fn default() -> Self {
        // The paper's standard setting: 5 local epochs, batch 64 (batch size
        // is baked into the AOT artifacts). The paper's lr is 0.001 on real
        // CIFAR-10; the synthetic substitute learns on the same curve shape
        // with 0.01 over 30 rounds (EXPERIMENTS.md documents the deviation).
        TrainParams {
            learning_rate: 0.01,
            local_epochs: 5,
        }
    }
}

/// Consensus section (Fig 2b).
#[derive(Clone, Debug, PartialEq)]
pub struct ConsensusConfig {
    /// Registry name: "majority_hash" | "score_vote" | "first".
    pub runnable: String,
    /// Worker names that behave maliciously (poison their aggregate).
    pub malicious_workers: Vec<String>,
    /// Delegate the decision to the blockchain contract instead of the
    /// logic controller (requires `chain.enabled`).
    pub on_chain: bool,
}

impl Default for ConsensusConfig {
    fn default() -> Self {
        ConsensusConfig {
            runnable: "majority_hash".into(),
            malicious_workers: Vec::new(),
            on_chain: false,
        }
    }
}

/// Pluggable blockchain section (paper §2.4).
#[derive(Clone, Debug, PartialEq)]
pub struct ChainConfig {
    pub enabled: bool,
    /// "ethereum" | "fabric".
    pub platform: String,
}

impl Default for ChainConfig {
    fn default() -> Self {
        ChainConfig {
            enabled: false,
            platform: "ethereum".into(),
        }
    }
}

/// How the client fleet is materialized (README "Cross-device scale").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PopulationMode {
    /// Every client is a resident [`ClientNode`](crate::node::ClientNode)
    /// built at scaffold time — memory O(population). The historical
    /// behaviour and the default.
    Eager,
    /// Per-client state (data shard, RNG stream, speed factor, adversary
    /// membership) is derived lazily from `(seed, name_index)` when a client
    /// is sampled into a round's cohort — memory O(model + cohort), which is
    /// what makes 100k–1M-client jobs feasible. Results are bitwise-identical
    /// to `Eager` (test-enforced contract).
    Virtual,
}

impl PopulationMode {
    pub fn parse(s: &str) -> Result<PopulationMode> {
        Ok(match s {
            "eager" => PopulationMode::Eager,
            "virtual" => PopulationMode::Virtual,
            other => bail!("job.population must be 'eager' or 'virtual', got '{other}'"),
        })
    }
}

/// A complete FLsim job.
#[derive(Clone, Debug)]
pub struct JobConfig {
    pub name: String,
    pub seed: u64,
    pub rounds: u64,
    pub backend: String,
    pub strategy: StrategyKind,
    pub topology: TopologyKind,
    pub n_clients: usize,
    pub n_workers: usize,
    pub dataset: DatasetSpec,
    pub train: TrainParams,
    pub consensus: ConsensusConfig,
    pub chain: ChainConfig,
    /// Floating-point reduction order = simulated hardware profile (RQ6).
    pub hw_profile: ReductionOrder,
    /// Stop waiting for stragglers after this many simulated seconds
    /// (Algorithm 1's `timeout()`); `None` waits forever.
    pub round_timeout_secs: Option<f64>,
    /// Per-edge-class link models of the virtual-clock network fabric (the
    /// `network:` section; defaults = the built-in EDGE/LAN/WAN constants).
    pub network: LinkPolicy,
    /// Client compute heterogeneity: each client's simulated train time is
    /// scaled by a deterministic factor in `[1, 1 + heterogeneity)` derived
    /// from the seed and the client name. `0.0` = homogeneous fleet.
    pub heterogeneity: f64,
    /// Virtual-clock round deadline: clients whose simulated
    /// download + train + upload time exceeds this are dropped through the
    /// Logic Controller's barrier timeout arm (Algorithm 1's straggler
    /// path). `None` = the clock is purely observational.
    pub round_deadline_secs: Option<f64>,
    /// Fraction of clients sampled per round (1.0 = all, paper default).
    pub client_fraction: f64,
    /// Client-side attack scenario (`adversary:` section). Inactive by
    /// default — see [`AdversaryConfig::is_active`].
    pub adversary: AdversaryConfig,
    /// Declarative fault schedules (`faults:` section): explicit drops and
    /// crashes, stochastic churn, replayable traces.
    pub faults: FaultsConfig,
    /// Byzantine-robust server aggregation (`aggregation: robust:`).
    pub robust_agg: RobustAggConfig,
    /// Composable transfer stack (`channel:` section): upload compression,
    /// DP clipping + noise with (ε, δ) accounting, secure-aggregation cost
    /// model. Inactive by default — see [`ChannelConfig::is_active`].
    pub channel: ChannelConfig,
    /// Worker threads for the round engine (client training + aggregation).
    /// `1` = fully sequential (the historical behaviour), `0` = one per
    /// available core. Any value produces bitwise-identical results — model
    /// hashes and byte counts never depend on this knob (see README
    /// "Determinism contract").
    pub parallelism: usize,
    /// Client fleet materialization: `Eager` (resident nodes, the default)
    /// or `Virtual` (cohort-lazy, for cross-device scale). Like
    /// `parallelism`, this knob is result-invariant and therefore excluded
    /// from [`JobConfig::canonical_json`].
    pub population: PopulationMode,
    /// Round-buffer arena (`arena: false` to disable): recycle the
    /// per-round `Arc<[f32]>` parameter allocations through
    /// [`crate::kvstore::RoundArena`]. Purely an allocator knob — values
    /// are copied bit-for-bit either way — so it is result-invariant and
    /// excluded from [`JobConfig::canonical_json`] like `parallelism`.
    pub arena: bool,
}

impl JobConfig {
    // ---------------------------------------------------------------------
    // Presets (the paper's standard setting: 10 clients, Dirichlet 0.5,
    // batch 64, 30 rounds, CNN on CIFAR-10).
    // ---------------------------------------------------------------------

    pub fn default_cnn(strategy: &str) -> JobConfig {
        let strategy = StrategyKind::parse(strategy, &Yaml::Null)
            .expect("valid strategy name");
        JobConfig {
            name: format!("{}_cnn", strategy.name()),
            seed: 42,
            rounds: 30,
            backend: "cnn".into(),
            topology: match strategy {
                StrategyKind::Fedstellar { .. } => TopologyKind::FullyConnected,
                _ => TopologyKind::ClientServer,
            },
            n_clients: 10,
            n_workers: 1,
            dataset: DatasetSpec::cifar_dirichlet(5000, 0.5),
            train: TrainParams::default(),
            consensus: ConsensusConfig::default(),
            chain: ChainConfig::default(),
            hw_profile: ReductionOrder::Sequential,
            round_timeout_secs: None,
            network: LinkPolicy::default(),
            heterogeneity: 0.0,
            round_deadline_secs: None,
            client_fraction: 1.0,
            adversary: AdversaryConfig::default(),
            faults: FaultsConfig::default(),
            robust_agg: RobustAggConfig::default(),
            channel: ChannelConfig::default(),
            parallelism: 1,
            population: PopulationMode::Eager,
            arena: true,
            strategy,
        }
    }

    /// Fig 12 preset: logistic regression on MNIST at scale.
    pub fn scale_logreg(n_clients: usize) -> JobConfig {
        let mut j = JobConfig::default_cnn("fedavg");
        j.name = format!("logreg_{n_clients}c");
        j.backend = "logreg".into();
        j.dataset = DatasetSpec {
            name: "mnist_synth".into(),
            n: 60_000,
            train_frac: 0.9,
            distribution: Distribution::Iid,
        };
        j.n_clients = n_clients;
        j.train.learning_rate = 0.05;
        j.train.local_epochs = 1;
        j.rounds = 10;
        j
    }

    // ---------------------------------------------------------------------
    // YAML loading
    // ---------------------------------------------------------------------

    pub fn from_yaml_str(src: &str) -> Result<JobConfig> {
        let y = Yaml::parse(src).map_err(|e| anyhow!("job config: {e}"))?;
        Self::from_yaml(&y)
    }

    pub fn from_yaml_file(path: &str) -> Result<JobConfig> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading job config {path}: {e}"))?;
        Self::from_yaml_str(&src)
    }

    pub fn from_yaml(y: &Yaml) -> Result<JobConfig> {
        let job = y.get("job").unwrap_or(&Yaml::Null);
        let name = get_str(job, "name").unwrap_or_else(|| "flsim_job".into());
        let seed = get_i64(job, "seed").unwrap_or(42) as u64;
        let rounds = get_i64(job, "rounds").unwrap_or(30) as u64;

        // (a) dataset
        let ds = y
            .get("dataset")
            .ok_or_else(|| anyhow!("job config: missing 'dataset' section"))?;
        let dataset = parse_dataset(ds)?;

        // (c) topology
        let topo = y
            .get("topology")
            .ok_or_else(|| anyhow!("job config: missing 'topology' section"))?;
        let topology = TopologyKind::parse(
            &get_str(topo, "kind").ok_or_else(|| anyhow!("topology: missing kind"))?,
        )?;
        let n_clients = get_i64(topo, "clients").unwrap_or(10) as usize;
        let n_workers = get_i64(topo, "workers").unwrap_or(1) as usize;
        if n_clients == 0 {
            bail!("topology: zero clients");
        }

        // (d) strategy
        let st = y
            .get("strategy")
            .ok_or_else(|| anyhow!("job config: missing 'strategy' section"))?;
        let strat_name =
            get_str(st, "name").ok_or_else(|| anyhow!("strategy: missing name"))?;
        let backend = get_str(st, "backend").unwrap_or_else(|| "cnn".into());
        let extra = st.get("extra_params").cloned().unwrap_or(Yaml::Null);
        let strategy = StrategyKind::parse(&strat_name, &extra)?;
        let mut train = TrainParams::default();
        if let Some(tp) = st.get("train_params") {
            if let Some(lr) = get_f64(tp, "learning_rate") {
                train.learning_rate = lr as f32;
            }
            if let Some(e) = get_i64(tp, "local_epochs") {
                train.local_epochs = e as usize;
            }
        }

        // (b) consensus
        let mut consensus = ConsensusConfig::default();
        if let Some(c) = y.get("consensus") {
            if let Some(r) = get_str(c, "runnable") {
                consensus.runnable = r;
            }
            if let Some(m) = c.get("malicious_workers").and_then(Yaml::as_seq) {
                consensus.malicious_workers = m
                    .iter()
                    .filter_map(|v| v.as_str().map(str::to_string))
                    .collect();
            }
            if let Some(b) = c.get("on_chain").and_then(Yaml::as_bool) {
                consensus.on_chain = b;
            }
        }

        // blockchain
        let mut chain = ChainConfig::default();
        if let Some(c) = y.get("chain") {
            if let Some(b) = c.get("enabled").and_then(Yaml::as_bool) {
                chain.enabled = b;
            }
            if let Some(p) = get_str(c, "platform") {
                chain.platform = p;
            }
        }
        if consensus.on_chain && !chain.enabled {
            bail!("consensus.on_chain requires chain.enabled: true");
        }

        let hw_profile = match get_str(y, "hardware_profile") {
            Some(s) => ReductionOrder::parse(&s)?,
            None => ReductionOrder::Sequential,
        };

        let round_timeout_secs = job.get("round_timeout_secs").and_then(Yaml::as_f64);
        let round_deadline_secs = job.get("round_deadline_secs").and_then(Yaml::as_f64);
        let heterogeneity = job
            .get("heterogeneity")
            .and_then(Yaml::as_f64)
            .unwrap_or(0.0);
        let mut network = LinkPolicy::default();
        if let Some(n) = y.get("network") {
            if let Some(l) = n.get("edge") {
                network.edge = parse_link(l, network.edge);
            }
            if let Some(l) = n.get("lan") {
                network.lan = parse_link(l, network.lan);
            }
            if let Some(l) = n.get("wan") {
                network.wan = parse_link(l, network.wan);
            }
        }
        let client_fraction = job
            .get("client_fraction")
            .and_then(Yaml::as_f64)
            .unwrap_or(1.0);
        let adversary = match y.get("adversary") {
            Some(a) => AdversaryConfig::from_yaml(a)?,
            None => AdversaryConfig::default(),
        };
        let faults = match y.get("faults") {
            Some(f) => FaultsConfig::from_yaml(f)?,
            None => FaultsConfig::default(),
        };
        let robust_agg = match y.get("aggregation") {
            Some(a) => RobustAggConfig::from_yaml(a)?,
            None => RobustAggConfig::default(),
        };
        let channel = match y.get("channel") {
            Some(c) => ChannelConfig::from_yaml(c)?,
            None => ChannelConfig::default(),
        };
        let parallelism = match get_i64(job, "parallelism").unwrap_or(1) {
            n if n < 0 => bail!("job.parallelism must be >= 0 (0 = auto), got {n}"),
            n => n as usize,
        };
        let population = match get_str(job, "population") {
            Some(s) => PopulationMode::parse(&s)?,
            None => PopulationMode::Eager,
        };
        let arena = job
            .get("arena")
            .and_then(Yaml::as_bool)
            .unwrap_or(true);

        let cfg = JobConfig {
            name,
            seed,
            rounds,
            backend,
            strategy,
            topology,
            n_clients,
            n_workers,
            dataset,
            train,
            consensus,
            chain,
            hw_profile,
            round_timeout_secs,
            network,
            heterogeneity,
            round_deadline_secs,
            client_fraction,
            adversary,
            faults,
            robust_agg,
            channel,
            parallelism,
            population,
            arena,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Canonical JSON of the job in a fixed key order. The campaign result
    /// cache keys cells on the SHA-256 of this string (plus the engine
    /// version), independent of YAML field order, spec formatting, or how
    /// the config was constructed.
    ///
    /// Two deliberate choices about what the key covers:
    /// * `parallelism`, `population` and `arena` are **excluded**: by the
    ///   determinism contract (README) any worker count, either fleet
    ///   materialization mode, and either buffer-recycling mode produce
    ///   bitwise-identical results, so a cached cell is valid at every
    ///   parallelism level, campaign schedule, population and arena mode.
    /// * `name` is **included**: the stored [`RunReport`]'s label must match
    ///   the cell name for resumed campaign reports to be byte-identical,
    ///   so a renamed-but-otherwise-identical cell re-runs rather than
    ///   serving a report under the old label.
    pub fn canonical_json(&self) -> Json {
        let opt_f64 = |v: Option<f64>| match v {
            Some(x) => Json::Num(x),
            None => Json::Null,
        };
        let link = |m: &LinkModel| {
            Json::obj(vec![
                ("latency_ms", Json::Num(m.latency_ms)),
                ("bandwidth_mbps", Json::Num(m.bandwidth_mbps)),
            ])
        };
        let mut pairs: Vec<(&str, Json)> = vec![
            ("name", Json::from(self.name.as_str())),
            // Decimal string, not a JSON number: a u64 seed >= 2^53 would
            // lose precision through the f64-backed Json::Num and collide
            // distinct seeds onto one cache key.
            ("seed", Json::Str(self.seed.to_string())),
            ("rounds", Json::from(self.rounds as usize)),
            ("backend", Json::from(self.backend.as_str())),
            ("strategy", strategy_canonical_json(&self.strategy)),
            ("topology", Json::from(self.topology.name())),
            ("n_clients", Json::from(self.n_clients)),
            ("n_workers", Json::from(self.n_workers)),
            (
                "dataset",
                Json::obj(vec![
                    ("name", Json::from(self.dataset.name.as_str())),
                    ("n", Json::from(self.dataset.n)),
                    ("train_frac", Json::Num(self.dataset.train_frac)),
                    (
                        "distribution",
                        match &self.dataset.distribution {
                            Distribution::Iid => Json::obj(vec![("kind", Json::from("iid"))]),
                            Distribution::Dirichlet { alpha } => Json::obj(vec![
                                ("kind", Json::from("dirichlet")),
                                ("alpha", Json::Num(*alpha)),
                            ]),
                            Distribution::Shards { shards_per_client } => Json::obj(vec![
                                ("kind", Json::from("shards")),
                                ("shards_per_client", Json::from(*shards_per_client)),
                            ]),
                        },
                    ),
                ]),
            ),
            (
                "train",
                Json::obj(vec![
                    ("learning_rate", Json::Num(self.train.learning_rate as f64)),
                    ("local_epochs", Json::from(self.train.local_epochs)),
                ]),
            ),
            (
                "consensus",
                Json::obj(vec![
                    ("runnable", Json::from(self.consensus.runnable.as_str())),
                    (
                        "malicious_workers",
                        Json::Arr(
                            self.consensus
                                .malicious_workers
                                .iter()
                                .map(|w| Json::from(w.as_str()))
                                .collect(),
                        ),
                    ),
                    ("on_chain", Json::from(self.consensus.on_chain)),
                ]),
            ),
            (
                "chain",
                Json::obj(vec![
                    ("enabled", Json::from(self.chain.enabled)),
                    ("platform", Json::from(self.chain.platform.as_str())),
                ]),
            ),
            ("hw_profile", Json::from(self.hw_profile.key())),
            ("round_timeout_secs", opt_f64(self.round_timeout_secs)),
            (
                "network",
                Json::obj(vec![
                    ("edge", link(&self.network.edge)),
                    ("lan", link(&self.network.lan)),
                    ("wan", link(&self.network.wan)),
                ]),
            ),
            ("heterogeneity", Json::Num(self.heterogeneity)),
            ("round_deadline_secs", opt_f64(self.round_deadline_secs)),
            ("client_fraction", Json::Num(self.client_fraction)),
        ];
        // Adversarial sections enter the key only when they can change the
        // run: an inactive section is contractually bitwise-identical to an
        // absent one, so it must hash identically too (pre-adversary cache
        // entries stay valid).
        if self.adversary.is_active() {
            pairs.push(("adversary", self.adversary.canonical_json()));
        }
        if self.faults.is_active() {
            pairs.push(("faults", self.faults.canonical_json()));
        }
        if self.robust_agg.is_active() {
            pairs.push(("robust_agg", self.robust_agg.canonical_json()));
        }
        if self.channel.is_active() {
            pairs.push(("channel", self.channel.canonical_json()));
        }
        Json::obj(pairs)
    }

    /// The round engine's worker count: `parallelism`, with `0` resolved to
    /// the number of available cores.
    pub fn effective_parallelism(&self) -> usize {
        match self.parallelism {
            0 => std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            n => n,
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.rounds == 0 {
            bail!("rounds must be >= 1");
        }
        if self.n_clients == 0 {
            bail!("need at least one client");
        }
        if !self.client_fraction.is_finite()
            || self.client_fraction <= 0.0
            || self.client_fraction > 1.0
        {
            bail!("client_fraction must be in (0, 1], got {}", self.client_fraction);
        }
        if self.train.learning_rate <= 0.0 {
            bail!("learning_rate must be positive");
        }
        if self.train.local_epochs == 0 {
            bail!("local_epochs must be >= 1");
        }
        // Eager mode gives every client a non-empty shard up front. Virtual
        // mode caps the shard count at the training-set size instead (clients
        // beyond it share shards), so a 60k-example dataset can back a
        // 1M-client population.
        if self.population == PopulationMode::Eager && self.dataset.n < self.n_clients {
            bail!(
                "dataset of {} examples cannot cover {} clients \
                 (use `population: virtual` for oversubscribed fleets)",
                self.dataset.n,
                self.n_clients
            );
        }
        if self.population == PopulationMode::Virtual {
            if !matches!(self.topology, TopologyKind::ClientServer) {
                bail!(
                    "population: virtual requires the client_server topology, got {}",
                    self.topology.name()
                );
            }
            if self.strategy.mode() != crate::strategy::StrategyMode::Global {
                bail!(
                    "population: virtual requires a global-mode strategy, got '{}'",
                    self.strategy.name()
                );
            }
            if self.n_clients > u32::MAX as usize {
                bail!(
                    "population: virtual supports at most {} clients, got {}",
                    u32::MAX,
                    self.n_clients
                );
            }
            // At cross-device scale the cohort — not the fleet — must stay
            // bounded: ceil(fraction * n) is what every round materializes.
            let cohort = (self.client_fraction * self.n_clients as f64).ceil();
            if self.n_clients > 100_000 && cohort > 100_000.0 {
                bail!(
                    "population: virtual with {} clients samples a {}-client cohort \
                     per round (client_fraction {}); lower client_fraction so the \
                     materialized cohort stays bounded",
                    self.n_clients,
                    cohort as u64,
                    self.client_fraction
                );
            }
        }
        for w in &self.consensus.malicious_workers {
            if !w.starts_with("worker_") && !w.starts_with("peer_") {
                bail!("malicious worker '{w}' does not name a worker/peer node");
            }
        }
        if !self.heterogeneity.is_finite() || self.heterogeneity < 0.0 {
            bail!("heterogeneity must be finite and >= 0, got {}", self.heterogeneity);
        }
        if let Some(d) = self.round_deadline_secs {
            if !d.is_finite() || d <= 0.0 {
                bail!("round_deadline_secs must be finite and positive, got {d}");
            }
        }
        self.adversary.validate()?;
        self.faults.validate()?;
        self.channel.validate()?;
        // The dpfl strategy *is* fedavg + channel.dp (pinned bitwise by
        // test); stacking both would clip and noise the aggregate twice.
        if self.channel.dp.is_some() && self.strategy.name() == "dpfl" {
            bail!(
                "channel.dp composes with any mean-shaped strategy — use \
                 'fedavg' (the dpfl strategy would apply DP twice)"
            );
        }
        for (node, _) in self.faults.drops.iter().chain(&self.faults.crashes) {
            if node.starts_with("client_") || node.starts_with("peer_") {
                let idx: Option<usize> = node.split('_').nth(1).and_then(|s| s.parse().ok());
                if let Some(i) = idx {
                    if i >= self.n_clients {
                        bail!(
                            "faults: '{node}' is out of range for {} clients",
                            self.n_clients
                        );
                    }
                }
            }
        }
        for (name, link) in [
            ("edge", self.network.edge),
            ("lan", self.network.lan),
            ("wan", self.network.wan),
        ] {
            if link.bandwidth_mbps <= 0.0 || link.latency_ms < 0.0 {
                bail!(
                    "network.{name}: bandwidth must be > 0 and latency >= 0 \
                     (got {} MBps, {} ms)",
                    link.bandwidth_mbps,
                    link.latency_ms
                );
            }
        }
        Ok(())
    }
}

/// Strategy selection + hyper-parameters in canonical key order (part of
/// [`JobConfig::canonical_json`]).
fn strategy_canonical_json(s: &StrategyKind) -> Json {
    let mut pairs: Vec<(&str, Json)> = vec![("name", Json::from(s.name()))];
    match s {
        StrategyKind::FedAvg | StrategyKind::Scaffold => {}
        StrategyKind::FedAvgM { server_momentum } => {
            pairs.push(("server_momentum", Json::Num(*server_momentum as f64)));
        }
        StrategyKind::FedProx { mu } => pairs.push(("mu", Json::Num(*mu as f64))),
        StrategyKind::Moon { mu, tau } => {
            pairs.push(("mu", Json::Num(*mu as f64)));
            pairs.push(("tau", Json::Num(*tau as f64)));
        }
        StrategyKind::DpFl { clip, sigma } => {
            pairs.push(("clip", Json::Num(*clip)));
            pairs.push(("sigma", Json::Num(*sigma)));
        }
        StrategyKind::FedOpt { kind, server_lr } => {
            pairs.push(("server_opt", Json::from(kind.name())));
            pairs.push(("server_lr", Json::Num(*server_lr as f64)));
        }
        StrategyKind::FlHc {
            cluster_round,
            n_clusters,
        } => {
            pairs.push(("cluster_round", Json::from(*cluster_round as usize)));
            pairs.push(("n_clusters", Json::from(*n_clusters)));
        }
        StrategyKind::Fedstellar { neighbors } => {
            pairs.push(("neighbors", Json::from(*neighbors)));
        }
    }
    Json::obj(pairs)
}

fn parse_link(y: &Yaml, base: LinkModel) -> LinkModel {
    let mut m = base;
    if let Some(v) = get_f64(y, "latency_ms") {
        m.latency_ms = v;
    }
    if let Some(v) = get_f64(y, "bandwidth_mbps") {
        m.bandwidth_mbps = v;
    }
    m
}

fn parse_dataset(ds: &Yaml) -> Result<DatasetSpec> {
    let name = get_str(ds, "name").ok_or_else(|| anyhow!("dataset: missing name"))?;
    let n = get_i64(ds, "n").unwrap_or(5000) as usize;
    let train_frac = ds
        .get("train_test_split")
        .and_then(|s| get_f64(s, "train"))
        .unwrap_or(0.8);
    let distribution = match ds.get("distribution") {
        None => Distribution::Iid,
        Some(d) => {
            let kind = get_str(d, "kind").unwrap_or_else(|| "iid".into());
            match kind.as_str() {
                "iid" | "uniform" => Distribution::Iid,
                "dirichlet" => Distribution::Dirichlet {
                    alpha: get_f64(d, "alpha").unwrap_or(0.5),
                },
                "shards" => Distribution::Shards {
                    shards_per_client: get_i64(d, "shards_per_client").unwrap_or(2) as usize,
                },
                other => bail!("unknown distribution kind '{other}'"),
            }
        }
    };
    if train_frac <= 0.0 || train_frac >= 1.0 {
        bail!("train fraction {train_frac} out of (0,1)");
    }
    Ok(DatasetSpec {
        name,
        n,
        train_frac,
        distribution,
    })
}

fn get_str(y: &Yaml, k: &str) -> Option<String> {
    y.get(k)?.as_str().map(str::to_string)
}

fn get_i64(y: &Yaml, k: &str) -> Option<i64> {
    y.get(k)?.as_i64()
}

fn get_f64(y: &Yaml, k: &str) -> Option<f64> {
    y.get(k)?.as_f64()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
job:
  name: scaffold_test
  seed: 7
  rounds: 12
  parallelism: 4
dataset:
  name: cifar10_synth
  n: 2000
  train_test_split: {train: 0.8, test: 0.2}
  distribution:
    kind: dirichlet
    alpha: 0.5
strategy:
  name: scaffold
  backend: cnn
  train_params:
    learning_rate: 0.01
    local_epochs: 3
topology:
  kind: client_server
  clients: 8
  workers: 2
consensus:
  runnable: majority_hash
  malicious_workers:
    - worker_1
hardware_profile: kahan
"#;

    #[test]
    fn parses_full_config() {
        let j = JobConfig::from_yaml_str(SAMPLE).unwrap();
        assert_eq!(j.name, "scaffold_test");
        assert_eq!(j.seed, 7);
        assert_eq!(j.rounds, 12);
        assert_eq!(j.strategy.name(), "scaffold");
        assert_eq!(j.n_clients, 8);
        assert_eq!(j.n_workers, 2);
        assert_eq!(j.train.learning_rate, 0.01);
        assert_eq!(j.train.local_epochs, 3);
        assert_eq!(j.consensus.malicious_workers, vec!["worker_1"]);
        assert_eq!(j.hw_profile, ReductionOrder::Kahan);
        assert_eq!(j.parallelism, 4);
        assert_eq!(j.effective_parallelism(), 4);
        assert_eq!(
            j.dataset.distribution,
            Distribution::Dirichlet { alpha: 0.5 }
        );
    }

    #[test]
    fn missing_sections_error() {
        assert!(JobConfig::from_yaml_str("job:\n  name: x\n").is_err());
    }

    #[test]
    fn presets_validate() {
        for s in [
            "fedavg", "fedavgm", "fedprox", "scaffold", "moon", "dpfl", "flhc",
            "fedstellar",
        ] {
            let j = JobConfig::default_cnn(s);
            j.validate().unwrap_or_else(|e| panic!("{s}: {e}"));
        }
        JobConfig::scale_logreg(100).validate().unwrap();
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut j = JobConfig::default_cnn("fedavg");
        j.rounds = 0;
        assert!(j.validate().is_err());
        let mut j = JobConfig::default_cnn("fedavg");
        j.train.learning_rate = -1.0;
        assert!(j.validate().is_err());
        let mut j = JobConfig::default_cnn("fedavg");
        j.consensus.malicious_workers = vec!["client_0".into()];
        assert!(j.validate().is_err());
        let mut j = JobConfig::default_cnn("fedavg");
        j.dataset.n = 3;
        assert!(j.validate().is_err());
    }

    #[test]
    fn parallelism_defaults_and_auto_resolves() {
        let mut j = JobConfig::default_cnn("fedavg");
        assert_eq!(j.parallelism, 1);
        assert_eq!(j.effective_parallelism(), 1);
        j.parallelism = 0; // auto
        assert!(j.effective_parallelism() >= 1);
        j.validate().unwrap();
    }

    #[test]
    fn network_heterogeneity_deadline_parse() {
        let yaml = r#"
job:
  name: fabric_test
  rounds: 2
  heterogeneity: 0.5
  round_deadline_secs: 12.5
dataset: {name: cifar10_synth, n: 600}
strategy: {name: fedavg, backend: cnn}
topology: {kind: client_server, clients: 4, workers: 1}
network:
  edge: {latency_ms: 100.0, bandwidth_mbps: 1.0}
  lan: {bandwidth_mbps: 250.0}
"#;
        let j = JobConfig::from_yaml_str(yaml).unwrap();
        assert_eq!(j.heterogeneity, 0.5);
        assert_eq!(j.round_deadline_secs, Some(12.5));
        assert_eq!(j.network.edge.latency_ms, 100.0);
        assert_eq!(j.network.edge.bandwidth_mbps, 1.0);
        // Partial override keeps the unmentioned field.
        assert_eq!(j.network.lan.bandwidth_mbps, 250.0);
        assert_eq!(j.network.lan.latency_ms, LinkModel::LAN.latency_ms);
        assert_eq!(j.network.wan, LinkModel::WAN);
    }

    #[test]
    fn fabric_keys_default_off() {
        let j = JobConfig::default_cnn("fedavg");
        assert_eq!(j.heterogeneity, 0.0);
        assert_eq!(j.round_deadline_secs, None);
        assert_eq!(j.network, LinkPolicy::default());
    }

    #[test]
    fn fabric_validation() {
        let mut j = JobConfig::default_cnn("fedavg");
        j.heterogeneity = -0.1;
        assert!(j.validate().is_err());
        let mut j = JobConfig::default_cnn("fedavg");
        j.round_deadline_secs = Some(0.0);
        assert!(j.validate().is_err());
        let mut j = JobConfig::default_cnn("fedavg");
        j.network.edge.bandwidth_mbps = 0.0;
        assert!(j.validate().is_err());
    }

    #[test]
    fn adversary_faults_aggregation_sections_parse() {
        let yaml = r#"
job:
  name: adv_test
  rounds: 4
dataset: {name: cifar10_synth, n: 600}
strategy: {name: fedavg, backend: cnn}
topology: {kind: client_server, clients: 4, workers: 1}
adversary:
  attack: scale
  attack_fraction: 0.25
  scale: 8.0
  nodes: [client_3]
faults:
  drops:
    - node: client_1
      round: 2
  churn:
    availability: 0.9
aggregation:
  robust: trimmed_mean
  f: 1
"#;
        let j = JobConfig::from_yaml_str(yaml).unwrap();
        assert_eq!(j.adversary.attack, crate::config::AttackKind::Scale);
        assert_eq!(j.adversary.attack_fraction, 0.25);
        assert_eq!(j.adversary.scale, 8.0);
        assert_eq!(j.adversary.nodes, vec!["client_3"]);
        assert_eq!(j.faults.drops, vec![("client_1".to_string(), 2)]);
        assert_eq!(j.faults.churn.unwrap().availability, 0.9);
        assert_eq!(j.robust_agg.kind, crate::config::RobustAggKind::TrimmedMean);
        assert_eq!(j.robust_agg.f, Some(1));
    }

    #[test]
    fn adversary_validation_via_job() {
        let mut j = JobConfig::default_cnn("fedavg");
        j.adversary.attack_fraction = f64::NAN;
        assert!(j.validate().is_err());
        let mut j = JobConfig::default_cnn("fedavg");
        j.adversary.nodes = vec!["worker_0".into()];
        assert!(j.validate().is_err());
        let mut j = JobConfig::default_cnn("fedavg");
        j.faults.drops.push(("client_99".into(), 2));
        assert!(j.validate().is_err(), "fault node beyond the fleet");
        let mut j = JobConfig::default_cnn("fedavg");
        j.heterogeneity = f64::NAN;
        assert!(j.validate().is_err());
        let mut j = JobConfig::default_cnn("fedavg");
        j.round_deadline_secs = Some(f64::NAN);
        assert!(j.validate().is_err());
        let mut j = JobConfig::default_cnn("fedavg");
        j.client_fraction = f64::NAN;
        assert!(j.validate().is_err());
    }

    #[test]
    fn canonical_json_ignores_inactive_adversary_sections() {
        let base = JobConfig::default_cnn("fedavg").canonical_json().to_string();
        // Inactive sections (defaults, zero fraction, no-op churn) hash
        // exactly like a pre-adversary config.
        let mut j = JobConfig::default_cnn("fedavg");
        j.adversary.attack_fraction = 0.0;
        j.faults.churn = Some(crate::config::ChurnConfig {
            availability: 1.0,
            from_round: 1,
        });
        assert_eq!(base, j.canonical_json().to_string());
        assert!(!base.contains("adversary"));
        // Active sections each change the key.
        let mut j = JobConfig::default_cnn("fedavg");
        j.adversary.attack_fraction = 0.3;
        assert_ne!(base, j.canonical_json().to_string());
        let mut j = JobConfig::default_cnn("fedavg");
        j.faults.drops.push(("client_1".into(), 2));
        assert_ne!(base, j.canonical_json().to_string());
        let mut j = JobConfig::default_cnn("fedavg");
        j.robust_agg.kind = crate::config::RobustAggKind::Krum;
        assert_ne!(base, j.canonical_json().to_string());
    }

    #[test]
    fn channel_section_parses() {
        let yaml = r#"
job:
  name: channel_test
  rounds: 3
dataset: {name: cifar10_synth, n: 600}
strategy: {name: fedavg, backend: cnn}
topology: {kind: client_server, clients: 4, workers: 1}
channel:
  compress:
    kind: quantize
    bits: 4
  dp:
    clip: 5.0
    sigma: 0.01
  secure_agg:
    threshold: 3
"#;
        let j = JobConfig::from_yaml_str(yaml).unwrap();
        assert_eq!(j.channel.compress.kind, crate::config::CompressKind::Quantize);
        assert_eq!(j.channel.compress.bits, 4);
        let dp = j.channel.dp.unwrap();
        assert_eq!(dp.clip, 5.0);
        assert_eq!(dp.sigma, 0.01);
        assert_eq!(dp.delta, crate::config::DpConfig::DEFAULT_DELTA);
        assert_eq!(j.channel.secure_agg.unwrap().threshold, 3);
        assert!(j.channel.is_active());
    }

    #[test]
    fn channel_dp_rejects_dpfl_strategy() {
        let mut j = JobConfig::default_cnn("dpfl");
        j.channel.dp = Some(crate::config::DpConfig {
            clip: 10.0,
            sigma: 0.005,
            delta: 1e-5,
        });
        assert!(j.validate().is_err(), "dpfl + channel.dp double-applies DP");
        let mut j = JobConfig::default_cnn("fedavg");
        j.channel.dp = Some(crate::config::DpConfig {
            clip: 10.0,
            sigma: 0.005,
            delta: 1e-5,
        });
        j.validate().unwrap();
    }

    #[test]
    fn canonical_json_ignores_inactive_channel() {
        let base = JobConfig::default_cnn("fedavg").canonical_json().to_string();
        // Default channel is inactive and invisible.
        assert!(!base.contains("channel"));
        // Each active stage changes the key.
        let mut j = JobConfig::default_cnn("fedavg");
        j.channel.compress =
            crate::config::ChannelConfig::parse_compress_axis("top_k:100").unwrap();
        assert_ne!(base, j.canonical_json().to_string());
        let mut j = JobConfig::default_cnn("fedavg");
        j.channel.dp = Some(crate::config::DpConfig {
            clip: 10.0,
            sigma: 0.01,
            delta: 1e-5,
        });
        assert_ne!(base, j.canonical_json().to_string());
        let mut j = JobConfig::default_cnn("fedavg");
        j.channel.secure_agg = Some(crate::config::SecureAggConfig { threshold: 2 });
        assert_ne!(base, j.canonical_json().to_string());
    }

    #[test]
    fn canonical_json_is_stable_and_excludes_parallelism() {
        let j = JobConfig::default_cnn("fedavg");
        let a = j.canonical_json().to_string();
        assert_eq!(a, j.canonical_json().to_string());
        let parsed = Json::parse(&a).unwrap();
        assert_eq!(
            parsed
                .get("strategy")
                .and_then(|s| s.get("name"))
                .and_then(Json::as_str),
            Some("fedavg")
        );
        // Parallelism is a wall-clock knob, not a result knob — it never
        // enters the canonical form (cache hits are schedule-invariant).
        let mut p8 = JobConfig::default_cnn("fedavg");
        p8.parallelism = 8;
        assert_eq!(a, p8.canonical_json().to_string());
        // Same for the buffer-recycling knob: where bytes land is not a
        // result property.
        let mut no_arena = JobConfig::default_cnn("fedavg");
        no_arena.arena = false;
        assert_eq!(a, no_arena.canonical_json().to_string());
        // Every other knob does.
        let mut seeded = JobConfig::default_cnn("fedavg");
        seeded.seed = 43;
        assert_ne!(a, seeded.canonical_json().to_string());
        // Seeds beyond f64's 2^53 integer range must stay distinct.
        let mut big_a = JobConfig::default_cnn("fedavg");
        big_a.seed = (1u64 << 53) + 1;
        let mut big_b = JobConfig::default_cnn("fedavg");
        big_b.seed = 1u64 << 53;
        assert_ne!(
            big_a.canonical_json().to_string(),
            big_b.canonical_json().to_string()
        );
    }

    #[test]
    fn population_mode_parses_and_validates() {
        // Default is eager.
        let j = JobConfig::default_cnn("fedavg");
        assert_eq!(j.population, PopulationMode::Eager);
        let sample = SAMPLE.replace("  parallelism: 4", "  parallelism: 4\n  population: virtual");
        let j = JobConfig::from_yaml_str(&sample).unwrap();
        assert_eq!(j.population, PopulationMode::Virtual);
        let bad = SAMPLE.replace("  parallelism: 4", "  parallelism: 4\n  population: ghostly");
        assert!(JobConfig::from_yaml_str(&bad).is_err());

        // Virtual relaxes the dataset-coverage rule (shards are shared)...
        let mut j = JobConfig::default_cnn("fedavg");
        j.n_clients = 10_000;
        assert!(j.validate().is_err(), "eager: 5000 examples, 10k clients");
        j.population = PopulationMode::Virtual;
        j.client_fraction = 0.001;
        j.validate().unwrap();
        // ...but restricts the orchestration shape to the standard
        // client_server round loop.
        let mut j = JobConfig::default_cnn("fedstellar");
        j.population = PopulationMode::Virtual;
        assert!(j.validate().is_err(), "virtual + decentralized");
        let mut j = JobConfig::default_cnn("flhc");
        j.population = PopulationMode::Virtual;
        assert!(j.validate().is_err(), "virtual + clustered");
        // Unbounded cohorts at scale are rejected up front.
        let mut j = JobConfig::default_cnn("fedavg");
        j.population = PopulationMode::Virtual;
        j.n_clients = 1_000_000;
        j.client_fraction = 1.0;
        assert!(j.validate().is_err(), "1M-client full-participation cohort");
        j.client_fraction = 0.0001;
        j.validate().unwrap();
    }

    #[test]
    fn canonical_json_excludes_population_mode() {
        let eager = JobConfig::default_cnn("fedavg");
        let mut virt = eager.clone();
        virt.population = PopulationMode::Virtual;
        // Same cache key: the modes are contractually bitwise-identical.
        assert_eq!(
            eager.canonical_json().to_string(),
            virt.canonical_json().to_string()
        );
    }

    #[test]
    fn on_chain_requires_chain() {
        let bad = SAMPLE.replace(
            "consensus:\n  runnable: majority_hash",
            "consensus:\n  on_chain: true\n  runnable: majority_hash",
        );
        assert!(JobConfig::from_yaml_str(&bad).is_err());
    }
}
