//! Adversarial-scenario configuration: the `adversary:`, `faults:` and
//! `aggregation:` job sections.
//!
//! These three knobs turn the previously code-only adversarial machinery
//! (fig10's hardwired malicious workers, `FaultPlan` construction,
//! `aggregate/robust.rs`) into declarative, campaign-sweepable config:
//!
//! * `adversary:` — a client-side attack library (label-flip, sign-flip,
//!   scaled model poisoning, colluding cohorts) with per-node assignment
//!   either by an explicit node list or by a seed-derived draw of an
//!   `attack_fraction` of the fleet;
//! * `faults:` — explicit drop/crash schedules, a stochastic per-round
//!   availability (churn) process, and replayable trace files, all feeding
//!   the existing [`crate::controller::sync::FaultPlan`] / barrier-timeout
//!   machinery;
//! * `aggregation: robust:` — Byzantine-robust aggregation (krum /
//!   trimmed-mean / coordinate-median) replacing the strategy's server-side
//!   mean.
//!
//! The determinism contract extends to all of them: every stochastic choice
//! (attacker assignment, churn draws) is derived from the job seed through
//! [`crate::util::rng::Rng::derive`], and an *inactive* section (absent,
//! empty, `attack_fraction: 0.0`, `availability: 1.0`) is bitwise-identical
//! to a config without it — it contributes nothing to the canonical cache
//! key and draws nothing from any RNG stream.

use anyhow::{anyhow, bail, Result};

use crate::util::json::Json;
use crate::util::yaml::Yaml;

// ---------------------------------------------------------------------------
// adversary:
// ---------------------------------------------------------------------------

/// Client-update attack applied at the update boundary of the round engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AttackKind {
    /// Shift every training label by one class (data poisoning): the client
    /// trains honestly on corrupted data.
    LabelFlip,
    /// Negate the trained parameters before upload.
    SignFlip,
    /// Gradient ascent ×λ: submit `start − λ·(trained − start)`, walking the
    /// model *up* the loss surface `λ` times as fast as honest clients walk
    /// it down.
    Scale,
    /// Colluding cohort: every attacker submits one *shared* poisoned vector
    /// (seed-derived), concentrating their weight on a single point.
    Collude,
}

impl AttackKind {
    pub fn parse(name: &str) -> Result<AttackKind> {
        Ok(match name {
            "label_flip" => AttackKind::LabelFlip,
            "sign_flip" => AttackKind::SignFlip,
            "scale" => AttackKind::Scale,
            "collude" => AttackKind::Collude,
            _ => bail!(
                "unknown attack '{name}' (supported: label_flip sign_flip scale collude)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            AttackKind::LabelFlip => "label_flip",
            AttackKind::SignFlip => "sign_flip",
            AttackKind::Scale => "scale",
            AttackKind::Collude => "collude",
        }
    }
}

/// The `adversary:` section: which attack, applied by whom.
#[derive(Clone, Debug, PartialEq)]
pub struct AdversaryConfig {
    pub attack: AttackKind,
    /// Fraction of the client fleet compromised, assigned by a seed-derived
    /// draw. `0.0` (the default) disables fraction-based assignment.
    pub attack_fraction: f64,
    /// Poison magnitude λ for `scale` / `collude`.
    pub scale: f64,
    /// Explicitly compromised nodes (unioned with the fraction draw).
    pub nodes: Vec<String>,
}

impl Default for AdversaryConfig {
    fn default() -> Self {
        AdversaryConfig {
            attack: AttackKind::Scale,
            attack_fraction: 0.0,
            scale: 10.0,
            nodes: Vec::new(),
        }
    }
}

impl AdversaryConfig {
    /// Whether any client is compromised. Inactive configs are contractually
    /// invisible: no cache-key contribution, no RNG draws, bitwise-identical
    /// runs.
    pub fn is_active(&self) -> bool {
        self.attack_fraction > 0.0 || !self.nodes.is_empty()
    }

    pub fn from_yaml(y: &Yaml) -> Result<AdversaryConfig> {
        let mut cfg = AdversaryConfig::default();
        if let Some(a) = y.get("attack").and_then(Yaml::as_str) {
            cfg.attack = AttackKind::parse(a)?;
        }
        if let Some(f) = y.get("attack_fraction") {
            cfg.attack_fraction = f
                .as_f64()
                .ok_or_else(|| anyhow!("adversary.attack_fraction must be a number"))?;
        }
        if let Some(s) = y.get("scale") {
            cfg.scale = s
                .as_f64()
                .ok_or_else(|| anyhow!("adversary.scale must be a number"))?;
        }
        if let Some(n) = y.get("nodes").and_then(Yaml::as_seq) {
            cfg.nodes = n
                .iter()
                .filter_map(|v| v.as_str().map(str::to_string))
                .collect();
        }
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if !self.attack_fraction.is_finite()
            || !(0.0..=1.0).contains(&self.attack_fraction)
        {
            bail!(
                "adversary.attack_fraction must be a finite fraction in [0, 1], got {}",
                self.attack_fraction
            );
        }
        if !self.scale.is_finite() || self.scale <= 0.0 {
            bail!(
                "adversary.scale must be a finite positive factor, got {}",
                self.scale
            );
        }
        for n in &self.nodes {
            if !n.starts_with("client_") && !n.starts_with("peer_") {
                bail!("adversary node '{n}' does not name a client/peer node");
            }
        }
        Ok(())
    }

    /// Canonical cache-key fragment — only ever called when active.
    pub fn canonical_json(&self) -> Json {
        Json::obj(vec![
            ("attack", Json::from(self.attack.name())),
            ("attack_fraction", Json::Num(self.attack_fraction)),
            ("scale", Json::Num(self.scale)),
            (
                "nodes",
                Json::Arr(self.nodes.iter().map(|n| Json::from(n.as_str())).collect()),
            ),
        ])
    }
}

// ---------------------------------------------------------------------------
// faults:
// ---------------------------------------------------------------------------

/// Stochastic availability churn: from `from_round` on, every client is up
/// in a given round with probability `availability`, drawn from a per-node
/// seed-derived stream.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChurnConfig {
    pub availability: f64,
    pub from_round: u64,
}

/// The `faults:` section: declarative fault schedules feeding the
/// [`crate::controller::sync::FaultPlan`] / barrier-timeout machinery.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultsConfig {
    /// `(node, round)` single-round drops.
    pub drops: Vec<(String, u64)>,
    /// `(node, from_round)` permanent crashes.
    pub crashes: Vec<(String, u64)>,
    pub churn: Option<ChurnConfig>,
}

impl FaultsConfig {
    /// Whether this config can affect the run. `availability: 1.0` churn is
    /// a no-op by construction (no draw ever fails) and is treated as
    /// inactive so it keeps the zero-adversary identity.
    pub fn is_active(&self) -> bool {
        !self.drops.is_empty()
            || !self.crashes.is_empty()
            || self.churn.map(|c| c.availability < 1.0).unwrap_or(false)
    }

    pub fn from_yaml(y: &Yaml) -> Result<FaultsConfig> {
        let mut cfg = FaultsConfig::default();
        if let Some(seq) = y.get("drops").and_then(Yaml::as_seq) {
            for d in seq {
                let node = d
                    .get("node")
                    .and_then(Yaml::as_str)
                    .ok_or_else(|| anyhow!("faults.drops entry: missing node"))?;
                let round = d
                    .get("round")
                    .and_then(Yaml::as_i64)
                    .ok_or_else(|| anyhow!("faults.drops entry: missing round"))?;
                if round < 1 {
                    bail!("faults.drops: round must be >= 1, got {round}");
                }
                cfg.drops.push((node.to_string(), round as u64));
            }
        }
        if let Some(seq) = y.get("crashes").and_then(Yaml::as_seq) {
            for c in seq {
                let node = c
                    .get("node")
                    .and_then(Yaml::as_str)
                    .ok_or_else(|| anyhow!("faults.crashes entry: missing node"))?;
                let round = c
                    .get("from_round")
                    .and_then(Yaml::as_i64)
                    .ok_or_else(|| anyhow!("faults.crashes entry: missing from_round"))?;
                if round < 1 {
                    bail!("faults.crashes: from_round must be >= 1, got {round}");
                }
                cfg.crashes.push((node.to_string(), round as u64));
            }
        }
        if let Some(c) = y.get("churn") {
            let availability = c
                .get("availability")
                .and_then(Yaml::as_f64)
                .ok_or_else(|| anyhow!("faults.churn: missing availability"))?;
            let from_round = match c.get("from_round").and_then(Yaml::as_i64) {
                None => 1,
                Some(r) if r >= 1 => r as u64,
                Some(r) => bail!("faults.churn.from_round must be >= 1, got {r}"),
            };
            cfg.churn = Some(ChurnConfig {
                availability,
                from_round,
            });
        }
        if let Some(path) = y.get("trace").and_then(Yaml::as_str) {
            let src = std::fs::read_to_string(path)
                .map_err(|e| anyhow!("faults.trace: reading {path}: {e}"))?;
            cfg.extend_from_trace(&src)
                .map_err(|e| anyhow!("faults.trace {path}: {e}"))?;
        }
        Ok(cfg)
    }

    /// Parse a fault trace: one event per line, `drop <node> <round>` or
    /// `crash <node> <from_round>`; `#` comments and blank lines ignored.
    /// Trace *contents* (not the path) become part of the config, so the
    /// canonical cache key covers exactly what the run will do.
    pub fn extend_from_trace(&mut self, src: &str) -> Result<()> {
        for (i, raw) in src.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let mut parts = line.split_whitespace();
            let (verb, node, round) = match (parts.next(), parts.next(), parts.next()) {
                (Some(v), Some(n), Some(r)) if parts.next().is_none() => (v, n, r),
                _ => bail!(
                    "line {}: expected 'drop <node> <round>' or \
                     'crash <node> <from_round>', got {raw:?}",
                    i + 1
                ),
            };
            let round: u64 = round
                .parse()
                .map_err(|_| anyhow!("line {}: bad round {round:?}", i + 1))?;
            if round < 1 {
                bail!("line {}: round must be >= 1", i + 1);
            }
            match verb {
                "drop" => self.drops.push((node.to_string(), round)),
                "crash" => self.crashes.push((node.to_string(), round)),
                _ => bail!("line {}: unknown event {verb:?}", i + 1),
            }
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        for (node, round) in self.drops.iter().chain(&self.crashes) {
            if !node.starts_with("client_")
                && !node.starts_with("worker_")
                && !node.starts_with("peer_")
            {
                bail!("faults: '{node}' does not name a client/worker/peer node");
            }
            if *round < 1 {
                bail!("faults: round for '{node}' must be >= 1, got {round}");
            }
        }
        if let Some(c) = self.churn {
            if !c.availability.is_finite() || !(0.0 < c.availability && c.availability <= 1.0) {
                bail!(
                    "faults.churn.availability must be a finite probability in (0, 1], got {}",
                    c.availability
                );
            }
            if c.from_round < 1 {
                bail!("faults.churn.from_round must be >= 1");
            }
        }
        Ok(())
    }

    /// Canonical cache-key fragment — only ever called when active.
    pub fn canonical_json(&self) -> Json {
        let events = |evs: &[(String, u64)]| {
            Json::Arr(
                evs.iter()
                    .map(|(n, r)| {
                        Json::obj(vec![
                            ("node", Json::from(n.as_str())),
                            ("round", Json::from(*r as usize)),
                        ])
                    })
                    .collect(),
            )
        };
        let mut pairs = vec![
            ("drops", events(&self.drops)),
            ("crashes", events(&self.crashes)),
        ];
        if let Some(c) = self.churn {
            pairs.push((
                "churn",
                Json::obj(vec![
                    ("availability", Json::Num(c.availability)),
                    ("from_round", Json::from(c.from_round as usize)),
                ]),
            ));
        }
        Json::obj(pairs)
    }
}

// ---------------------------------------------------------------------------
// aggregation: robust:
// ---------------------------------------------------------------------------

/// Byzantine-robust server-side aggregation (see `aggregate/robust.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RobustAggKind {
    /// The strategy's own aggregation (weighted mean for FedAvg-family).
    None,
    Krum,
    TrimmedMean,
    Median,
}

impl RobustAggKind {
    pub fn parse(name: &str) -> Result<RobustAggKind> {
        Ok(match name {
            "none" => RobustAggKind::None,
            "krum" => RobustAggKind::Krum,
            "trimmed_mean" => RobustAggKind::TrimmedMean,
            "median" | "coordinate_median" => RobustAggKind::Median,
            _ => bail!(
                "unknown robust aggregator '{name}' (supported: none krum trimmed_mean median)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            RobustAggKind::None => "none",
            RobustAggKind::Krum => "krum",
            RobustAggKind::TrimmedMean => "trimmed_mean",
            RobustAggKind::Median => "median",
        }
    }
}

/// The `aggregation:` section: `robust: none|krum|trimmed_mean|median` plus
/// an optional explicit Byzantine count `f` (defaults to the number of
/// configured adversaries among the round's updates, min 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RobustAggConfig {
    pub kind: RobustAggKind,
    pub f: Option<usize>,
}

impl Default for RobustAggConfig {
    fn default() -> Self {
        RobustAggConfig {
            kind: RobustAggKind::None,
            f: None,
        }
    }
}

impl RobustAggConfig {
    pub fn is_active(&self) -> bool {
        self.kind != RobustAggKind::None
    }

    pub fn from_yaml(y: &Yaml) -> Result<RobustAggConfig> {
        let mut cfg = RobustAggConfig::default();
        if let Some(r) = y.get("robust").and_then(Yaml::as_str) {
            cfg.kind = RobustAggKind::parse(r)?;
        }
        if let Some(f) = y.get("f") {
            let f = f
                .as_i64()
                .ok_or_else(|| anyhow!("aggregation.f must be an integer"))?;
            if f < 1 {
                bail!("aggregation.f must be >= 1, got {f}");
            }
            cfg.f = Some(f as usize);
        }
        Ok(cfg)
    }

    /// Campaign-axis form: `krum` / `krum:2` / `trimmed_mean:1` / `none`.
    pub fn parse_axis(value: &str) -> Result<RobustAggConfig> {
        let (kind, f) = match value.split_once(':') {
            Some((k, f)) => {
                let f: usize = f
                    .parse()
                    .map_err(|_| anyhow!("robust_agg '{value}': bad f {f:?}"))?;
                if f < 1 {
                    bail!("robust_agg '{value}': f must be >= 1");
                }
                (RobustAggKind::parse(k)?, Some(f))
            }
            None => (RobustAggKind::parse(value)?, None),
        };
        Ok(RobustAggConfig { kind, f })
    }

    /// Canonical cache-key fragment — only ever called when active.
    pub fn canonical_json(&self) -> Json {
        Json::obj(vec![
            ("robust", Json::from(self.kind.name())),
            (
                "f",
                match self.f {
                    Some(f) => Json::from(f),
                    None => Json::Null,
                },
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attack_kinds_round_trip() {
        for name in ["label_flip", "sign_flip", "scale", "collude"] {
            assert_eq!(AttackKind::parse(name).unwrap().name(), name);
        }
        assert!(AttackKind::parse("dos").is_err());
    }

    #[test]
    fn adversary_defaults_inactive() {
        let a = AdversaryConfig::default();
        assert!(!a.is_active());
        a.validate().unwrap();
        let y = Yaml::parse("attack: sign_flip\nattack_fraction: 0.3\nscale: 5.0\n").unwrap();
        let a = AdversaryConfig::from_yaml(&y).unwrap();
        assert_eq!(a.attack, AttackKind::SignFlip);
        assert_eq!(a.attack_fraction, 0.3);
        assert_eq!(a.scale, 5.0);
        assert!(a.is_active());
        a.validate().unwrap();
    }

    #[test]
    fn adversary_validation_rejects_bad_values() {
        let mut a = AdversaryConfig::default();
        a.attack_fraction = 1.5;
        assert!(a.validate().is_err());
        a.attack_fraction = -0.1;
        assert!(a.validate().is_err());
        a.attack_fraction = f64::NAN;
        assert!(a.validate().is_err());
        let mut a = AdversaryConfig::default();
        a.scale = 0.0;
        assert!(a.validate().is_err());
        a.scale = f64::INFINITY;
        assert!(a.validate().is_err());
        let mut a = AdversaryConfig::default();
        a.nodes = vec!["worker_0".into()];
        assert!(a.validate().is_err());
        a.nodes = vec!["client_2".into()];
        a.validate().unwrap();
        assert!(a.is_active());
    }

    #[test]
    fn faults_from_yaml_and_activity() {
        let y = Yaml::parse(
            "drops:\n  - node: client_1\n    round: 3\ncrashes:\n  - node: client_2\n    \
             from_round: 4\nchurn:\n  availability: 0.9\n  from_round: 2\n",
        )
        .unwrap();
        let f = FaultsConfig::from_yaml(&y).unwrap();
        assert_eq!(f.drops, vec![("client_1".to_string(), 3)]);
        assert_eq!(f.crashes, vec![("client_2".to_string(), 4)]);
        assert_eq!(
            f.churn,
            Some(ChurnConfig {
                availability: 0.9,
                from_round: 2
            })
        );
        assert!(f.is_active());
        f.validate().unwrap();
        // availability 1.0 alone is a no-op: inactive by contract.
        let f = FaultsConfig {
            churn: Some(ChurnConfig {
                availability: 1.0,
                from_round: 1,
            }),
            ..FaultsConfig::default()
        };
        assert!(!f.is_active());
        f.validate().unwrap();
        assert!(!FaultsConfig::default().is_active());
    }

    #[test]
    fn faults_validation_rejects_bad_values() {
        let mut f = FaultsConfig::default();
        f.drops.push(("gateway_1".into(), 2));
        assert!(f.validate().is_err());
        let mut f = FaultsConfig::default();
        f.churn = Some(ChurnConfig {
            availability: 0.0,
            from_round: 1,
        });
        assert!(f.validate().is_err());
        f.churn = Some(ChurnConfig {
            availability: f64::NAN,
            from_round: 1,
        });
        assert!(f.validate().is_err());
        f.churn = Some(ChurnConfig {
            availability: 1.5,
            from_round: 1,
        });
        assert!(f.validate().is_err());
        // Round-0 events would break scaffold's all-nodes barrier.
        let y = Yaml::parse("drops:\n  - node: client_1\n    round: 0\n").unwrap();
        assert!(FaultsConfig::from_yaml(&y).is_err());
    }

    #[test]
    fn trace_round_trip_and_errors() {
        let mut f = FaultsConfig::default();
        f.extend_from_trace(
            "# header\ndrop client_1 3\n\ncrash worker_0 5  # mid-run failure\n",
        )
        .unwrap();
        assert_eq!(f.drops, vec![("client_1".to_string(), 3)]);
        assert_eq!(f.crashes, vec![("worker_0".to_string(), 5)]);
        f.validate().unwrap();
        let mut f = FaultsConfig::default();
        assert!(f.extend_from_trace("reboot client_1 3\n").is_err());
        assert!(f.extend_from_trace("drop client_1\n").is_err());
        assert!(f.extend_from_trace("drop client_1 zero\n").is_err());
        assert!(f.extend_from_trace("drop client_1 0\n").is_err());
    }

    #[test]
    fn robust_agg_parse_and_axis() {
        assert!(!RobustAggConfig::default().is_active());
        let y = Yaml::parse("robust: krum\nf: 2\n").unwrap();
        let r = RobustAggConfig::from_yaml(&y).unwrap();
        assert_eq!(r.kind, RobustAggKind::Krum);
        assert_eq!(r.f, Some(2));
        assert!(r.is_active());
        let r = RobustAggConfig::parse_axis("trimmed_mean:1").unwrap();
        assert_eq!(r.kind, RobustAggKind::TrimmedMean);
        assert_eq!(r.f, Some(1));
        let r = RobustAggConfig::parse_axis("median").unwrap();
        assert_eq!(r.kind, RobustAggKind::Median);
        assert_eq!(r.f, None);
        assert!(RobustAggConfig::parse_axis("krum:0").is_err());
        assert!(RobustAggConfig::parse_axis("geometric").is_err());
        assert!(RobustAggConfig::from_yaml(&Yaml::parse("f: 0\n").unwrap()).is_err());
    }

    #[test]
    fn canonical_fragments_are_stable() {
        let a = AdversaryConfig {
            attack: AttackKind::Scale,
            attack_fraction: 0.3,
            scale: 10.0,
            nodes: vec!["client_1".into()],
        };
        assert_eq!(
            a.canonical_json().to_string(),
            a.canonical_json().to_string()
        );
        let mut f = FaultsConfig::default();
        f.drops.push(("client_1".into(), 3));
        assert_eq!(
            f.canonical_json().to_string(),
            f.canonical_json().to_string()
        );
    }
}
