//! Composable channel configuration: the `channel:` job section.
//!
//! Every logical client→server transfer can pass through a per-job channel
//! stack with three independently-toggled stages, applied in a fixed order
//! at the client-update boundary of the round engine:
//!
//! 1. **DP** (`dp: {clip, sigma, delta}`) — server-side DP-FedAvg treatment
//!    (Geyer et al.): each client delta is L2-clipped to `clip`, and the
//!    aggregated mean receives Gaussian noise with std `sigma·clip/n`. A DP
//!    accountant ([`crate::metrics::privacy`]) tracks the cumulative (ε, δ)
//!    spend per round into RoundMetrics and campaign reports. `fedavg` plus
//!    `channel.dp` is bitwise-identical to the legacy `dpfl` strategy
//!    (pinned by test), which it supersedes.
//! 2. **Compression** (`compress: {kind: none|top_k|quantize, k|bits}`) —
//!    client deltas are compressed before upload and decompressed
//!    server-side; the network fabric meters the transfer at the compressed
//!    [`crate::aggregate::compress::CompressedUpdate::wire_bytes`], so
//!    `net_bytes` and `sim_round_secs` honestly reflect the channel.
//! 3. **Secure aggregation** (`secure_agg: {threshold}`) — a cost model of
//!    masked-share exchange (Bonawitz et al.): each participating client
//!    additionally exchanges pairwise mask shares, dropped clients cost a
//!    share-recovery round among survivors, and rounds with fewer than
//!    `threshold` surviving updates abort. Simulation-only: prices the
//!    protocol through the network fabric without changing aggregation
//!    results.
//!
//! The determinism contract from the adversary sections extends here: all
//! channel randomness (quantization dither) derives from the job seed via
//! [`crate::util::rng::Rng::derive`], and an *inactive* section (absent,
//! `compress.kind: none`, no `dp:`, no `secure_agg:`) is bitwise-identical
//! to a config without it — no cache-key contribution, no RNG draws.

use anyhow::{anyhow, bail, Result};

use crate::util::json::Json;
use crate::util::yaml::Yaml;

/// Upload compression scheme (see [`crate::aggregate::compress`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CompressKind {
    /// Dense f32 upload (the identity channel).
    None,
    /// Keep the `k` largest-magnitude delta coordinates.
    TopK,
    /// Uniform `bits`-bit quantization with stochastic rounding.
    Quantize,
}

impl CompressKind {
    pub fn parse(name: &str) -> Result<CompressKind> {
        Ok(match name {
            "none" => CompressKind::None,
            "top_k" | "topk" => CompressKind::TopK,
            "quantize" => CompressKind::Quantize,
            _ => bail!("unknown compression '{name}' (supported: none top_k quantize)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            CompressKind::None => "none",
            CompressKind::TopK => "top_k",
            CompressKind::Quantize => "quantize",
        }
    }
}

/// The `channel.compress:` stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CompressConfig {
    pub kind: CompressKind,
    /// Coordinates kept per upload (`top_k` only).
    pub k: usize,
    /// Code width in bits, 1..=16 (`quantize` only).
    pub bits: u8,
}

impl Default for CompressConfig {
    fn default() -> Self {
        CompressConfig {
            kind: CompressKind::None,
            k: 0,
            bits: 0,
        }
    }
}

impl CompressConfig {
    pub fn is_active(&self) -> bool {
        self.kind != CompressKind::None
    }

    /// Human-readable axis label (`none` / `top_k:8000` / `quantize:4`),
    /// the inverse of [`ChannelConfig::parse_compress_axis`].
    pub fn label(&self) -> String {
        match self.kind {
            CompressKind::None => "none".into(),
            CompressKind::TopK => format!("top_k:{}", self.k),
            CompressKind::Quantize => format!("quantize:{}", self.bits),
        }
    }

    pub fn validate(&self) -> Result<()> {
        match self.kind {
            CompressKind::None => {}
            CompressKind::TopK => {
                if self.k < 1 {
                    bail!("channel.compress: top_k requires k >= 1, got {}", self.k);
                }
            }
            CompressKind::Quantize => {
                if !(1..=16).contains(&self.bits) {
                    bail!(
                        "channel.compress: quantize requires bits in 1..=16, got {}",
                        self.bits
                    );
                }
            }
        }
        Ok(())
    }

    /// Canonical cache-key fragment — only ever called when active.
    pub fn canonical_json(&self) -> Json {
        let mut pairs = vec![("kind", Json::from(self.kind.name()))];
        match self.kind {
            CompressKind::TopK => pairs.push(("k", Json::from(self.k))),
            CompressKind::Quantize => pairs.push(("bits", Json::from(self.bits as usize))),
            CompressKind::None => {}
        }
        Json::obj(pairs)
    }
}

/// The `channel.dp:` stage — DP-FedAvg server-side clipping + noise with
/// per-round (ε, δ) accounting.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DpConfig {
    /// L2 clipping bound applied to every client delta.
    pub clip: f64,
    /// Noise multiplier: aggregate noise std is `sigma·clip/n`.
    pub sigma: f64,
    /// Per-round δ for the (ε, δ) accountant.
    pub delta: f64,
}

impl DpConfig {
    pub const DEFAULT_CLIP: f64 = 10.0;
    pub const DEFAULT_DELTA: f64 = 1e-5;

    pub fn validate(&self) -> Result<()> {
        if !self.clip.is_finite() || self.clip <= 0.0 {
            bail!("channel.dp.clip must be a finite positive bound, got {}", self.clip);
        }
        if !self.sigma.is_finite() || self.sigma < 0.0 {
            bail!(
                "channel.dp.sigma must be a finite non-negative multiplier, got {}",
                self.sigma
            );
        }
        if !self.delta.is_finite() || !(0.0 < self.delta && self.delta < 1.0) {
            bail!("channel.dp.delta must be in (0, 1), got {}", self.delta);
        }
        Ok(())
    }

    /// Canonical cache-key fragment — only ever called when active.
    pub fn canonical_json(&self) -> Json {
        Json::obj(vec![
            ("clip", Json::Num(self.clip)),
            ("sigma", Json::Num(self.sigma)),
            ("delta", Json::Num(self.delta)),
        ])
    }
}

/// The `channel.secure_agg:` stage — masked-share exchange cost model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SecureAggConfig {
    /// Minimum surviving updates required to unmask the aggregate.
    pub threshold: usize,
}

impl SecureAggConfig {
    pub fn validate(&self) -> Result<()> {
        if self.threshold < 1 {
            bail!(
                "channel.secure_agg.threshold must be >= 1, got {}",
                self.threshold
            );
        }
        Ok(())
    }

    /// Canonical cache-key fragment — only ever called when active.
    pub fn canonical_json(&self) -> Json {
        Json::obj(vec![("threshold", Json::from(self.threshold))])
    }
}

/// The `channel:` section: the composable per-job transfer stack.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ChannelConfig {
    pub compress: CompressConfig,
    pub dp: Option<DpConfig>,
    pub secure_agg: Option<SecureAggConfig>,
}

impl ChannelConfig {
    /// Whether any stage is configured. Inactive channels are contractually
    /// invisible: no cache-key contribution, no RNG draws, bitwise-identical
    /// runs.
    pub fn is_active(&self) -> bool {
        self.compress.is_active() || self.dp.is_some() || self.secure_agg.is_some()
    }

    pub fn from_yaml(y: &Yaml) -> Result<ChannelConfig> {
        let mut cfg = ChannelConfig::default();
        if let Some(c) = y.get("compress") {
            let kind = c
                .get("kind")
                .and_then(Yaml::as_str)
                .ok_or_else(|| anyhow!("channel.compress: missing kind"))?;
            cfg.compress.kind = CompressKind::parse(kind)?;
            if let Some(k) = c.get("k") {
                let k = k
                    .as_i64()
                    .ok_or_else(|| anyhow!("channel.compress.k must be an integer"))?;
                if k < 1 {
                    bail!("channel.compress.k must be >= 1, got {k}");
                }
                cfg.compress.k = k as usize;
            }
            if let Some(b) = c.get("bits") {
                let b = b
                    .as_i64()
                    .ok_or_else(|| anyhow!("channel.compress.bits must be an integer"))?;
                if !(1..=16).contains(&b) {
                    bail!("channel.compress.bits must be in 1..=16, got {b}");
                }
                cfg.compress.bits = b as u8;
            }
        }
        if let Some(d) = y.get("dp") {
            let f = |key: &str, default: f64| -> Result<f64> {
                match d.get(key) {
                    None => Ok(default),
                    Some(v) => v
                        .as_f64()
                        .ok_or_else(|| anyhow!("channel.dp.{key} must be a number")),
                }
            };
            cfg.dp = Some(DpConfig {
                clip: f("clip", DpConfig::DEFAULT_CLIP)?,
                sigma: d
                    .get("sigma")
                    .and_then(Yaml::as_f64)
                    .ok_or_else(|| anyhow!("channel.dp: missing sigma"))?,
                delta: f("delta", DpConfig::DEFAULT_DELTA)?,
            });
        }
        if let Some(s) = y.get("secure_agg") {
            let threshold = s
                .get("threshold")
                .and_then(Yaml::as_i64)
                .ok_or_else(|| anyhow!("channel.secure_agg: missing threshold"))?;
            if threshold < 1 {
                bail!("channel.secure_agg.threshold must be >= 1, got {threshold}");
            }
            cfg.secure_agg = Some(SecureAggConfig {
                threshold: threshold as usize,
            });
        }
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        self.compress.validate()?;
        if let Some(dp) = &self.dp {
            dp.validate()?;
        }
        if let Some(sa) = &self.secure_agg {
            sa.validate()?;
        }
        Ok(())
    }

    /// Campaign-axis form for the `compress` axis:
    /// `none` / `top_k:<k>` / `quantize:<bits>`.
    pub fn parse_compress_axis(value: &str) -> Result<CompressConfig> {
        let mut cfg = CompressConfig::default();
        match value.split_once(':') {
            None => {
                cfg.kind = CompressKind::parse(value)?;
                if cfg.kind != CompressKind::None {
                    bail!(
                        "compress '{value}': {} needs a parameter ({})",
                        cfg.kind.name(),
                        if cfg.kind == CompressKind::TopK {
                            "top_k:<k>"
                        } else {
                            "quantize:<bits>"
                        }
                    );
                }
            }
            Some((kind, param)) => {
                cfg.kind = CompressKind::parse(kind)?;
                let p: i64 = param
                    .parse()
                    .map_err(|_| anyhow!("compress '{value}': bad parameter {param:?}"))?;
                match cfg.kind {
                    CompressKind::None => bail!("compress '{value}': none takes no parameter"),
                    CompressKind::TopK => {
                        if p < 1 {
                            bail!("compress '{value}': k must be >= 1");
                        }
                        cfg.k = p as usize;
                    }
                    CompressKind::Quantize => {
                        if !(1..=16).contains(&p) {
                            bail!("compress '{value}': bits must be in 1..=16");
                        }
                        cfg.bits = p as u8;
                    }
                }
            }
        }
        Ok(cfg)
    }

    /// Canonical cache-key fragment — only ever called when active, and
    /// only includes the stages that are themselves active, so toggling an
    /// unrelated stage never perturbs the others' key bytes.
    pub fn canonical_json(&self) -> Json {
        let mut pairs = Vec::new();
        if self.compress.is_active() {
            pairs.push(("compress", self.compress.canonical_json()));
        }
        if let Some(dp) = &self.dp {
            pairs.push(("dp", dp.canonical_json()));
        }
        if let Some(sa) = &self.secure_agg {
            pairs.push(("secure_agg", sa.canonical_json()));
        }
        Json::obj(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compress_kinds_round_trip() {
        for name in ["none", "top_k", "quantize"] {
            assert_eq!(CompressKind::parse(name).unwrap().name(), name);
        }
        assert!(CompressKind::parse("gzip").is_err());
    }

    #[test]
    fn defaults_inactive_and_valid() {
        let c = ChannelConfig::default();
        assert!(!c.is_active());
        c.validate().unwrap();
        assert!(!c.compress.is_active());
    }

    #[test]
    fn from_yaml_full_stack() {
        let y = Yaml::parse(
            "compress:\n  kind: top_k\n  k: 500\ndp:\n  clip: 5.0\n  sigma: 0.01\n  \
             delta: 0.00001\nsecure_agg:\n  threshold: 3\n",
        )
        .unwrap();
        let c = ChannelConfig::from_yaml(&y).unwrap();
        assert_eq!(c.compress.kind, CompressKind::TopK);
        assert_eq!(c.compress.k, 500);
        let dp = c.dp.unwrap();
        assert_eq!(dp.clip, 5.0);
        assert_eq!(dp.sigma, 0.01);
        assert_eq!(c.secure_agg.unwrap().threshold, 3);
        assert!(c.is_active());
        c.validate().unwrap();
    }

    #[test]
    fn dp_defaults_fill_in() {
        let y = Yaml::parse("dp:\n  sigma: 0.02\n").unwrap();
        let c = ChannelConfig::from_yaml(&y).unwrap();
        let dp = c.dp.unwrap();
        assert_eq!(dp.clip, DpConfig::DEFAULT_CLIP);
        assert_eq!(dp.delta, DpConfig::DEFAULT_DELTA);
        assert_eq!(dp.sigma, 0.02);
        // sigma is mandatory — a dp section without it is an error, not a
        // silently-noiseless channel.
        let y = Yaml::parse("dp:\n  clip: 1.0\n").unwrap();
        assert!(ChannelConfig::from_yaml(&y).is_err());
    }

    #[test]
    fn validation_rejects_bad_values() {
        let mut c = ChannelConfig::default();
        c.compress.kind = CompressKind::TopK; // k defaults to 0
        assert!(c.validate().is_err());
        c.compress.k = 8;
        c.validate().unwrap();
        let mut c = ChannelConfig::default();
        c.compress.kind = CompressKind::Quantize;
        c.compress.bits = 0;
        assert!(c.validate().is_err());
        c.compress.bits = 17;
        assert!(c.validate().is_err());
        c.compress.bits = 16;
        c.validate().unwrap();
        for (clip, sigma, delta) in [
            (0.0, 0.01, 1e-5),
            (f64::NAN, 0.01, 1e-5),
            (1.0, -0.1, 1e-5),
            (1.0, f64::INFINITY, 1e-5),
            (1.0, 0.01, 0.0),
            (1.0, 0.01, 1.0),
        ] {
            let c = ChannelConfig {
                dp: Some(DpConfig { clip, sigma, delta }),
                ..ChannelConfig::default()
            };
            assert!(c.validate().is_err(), "accepted clip={clip} sigma={sigma} delta={delta}");
        }
        let c = ChannelConfig {
            secure_agg: Some(SecureAggConfig { threshold: 0 }),
            ..ChannelConfig::default()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn compress_axis_round_trips() {
        let c = ChannelConfig::parse_compress_axis("none").unwrap();
        assert_eq!(c.kind, CompressKind::None);
        assert_eq!(c.label(), "none");
        let c = ChannelConfig::parse_compress_axis("top_k:8000").unwrap();
        assert_eq!(c.kind, CompressKind::TopK);
        assert_eq!(c.k, 8000);
        assert_eq!(c.label(), "top_k:8000");
        let c = ChannelConfig::parse_compress_axis("quantize:4").unwrap();
        assert_eq!(c.kind, CompressKind::Quantize);
        assert_eq!(c.bits, 4);
        assert_eq!(c.label(), "quantize:4");
        assert!(ChannelConfig::parse_compress_axis("top_k").is_err());
        assert!(ChannelConfig::parse_compress_axis("top_k:0").is_err());
        assert!(ChannelConfig::parse_compress_axis("quantize:17").is_err());
        assert!(ChannelConfig::parse_compress_axis("none:1").is_err());
        assert!(ChannelConfig::parse_compress_axis("rle:2").is_err());
    }

    #[test]
    fn canonical_fragment_covers_only_active_stages() {
        let mut c = ChannelConfig::default();
        c.compress = ChannelConfig::parse_compress_axis("quantize:8").unwrap();
        let compress_only = c.canonical_json().to_string();
        assert!(compress_only.contains("quantize"));
        assert!(!compress_only.contains("dp"));
        c.dp = Some(DpConfig {
            clip: 10.0,
            sigma: 0.005,
            delta: 1e-5,
        });
        let with_dp = c.canonical_json().to_string();
        assert_ne!(compress_only, with_dp);
        assert!(with_dp.contains("sigma"));
        // Stable across calls.
        assert_eq!(with_dp, c.canonical_json().to_string());
    }
}
