//! Minimal, offline drop-in for the subset of `anyhow` this workspace uses:
//! `Error`, `Result<T>`, the `anyhow!` / `bail!` macros, and the `Context`
//! extension trait with `context` / `with_context`.
//!
//! Semantics mirror upstream where they matter to callers:
//! * `Display` shows the outermost message; `{:#}` shows the whole chain
//!   joined with `": "`.
//! * `Debug` ({:?}) prints the message plus a `Caused by:` list.
//! * Any `std::error::Error + Send + Sync + 'static` converts via `?`
//!   (`Error` itself deliberately does **not** implement `std::error::Error`,
//!   exactly like upstream, so the blanket `From` impl stays coherent).

use std::fmt;

/// An error chain; `chain[0]` is the outermost (most recent) message.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }

    /// Outermost-first iterator over the message chain.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    fn from_std<E: std::error::Error + ?Sized>(e: &E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        for cause in &self.chain[1..] {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::from_std(&e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `context` / `with_context` to any `Result` whose
/// error is printable (upstream requires `std::error::Error`; printable is a
/// superset that also covers our own `Error`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = anyhow!("outer {}", 42);
        assert_eq!(e.to_string(), "outer 42");
        let e = e.context("while doing x");
        assert_eq!(e.to_string(), "while doing x");
        assert_eq!(format!("{e:#}"), "while doing x: outer 42");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("no such file"));
    }

    #[test]
    fn with_context_wraps() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("reading {}", "cfg")).unwrap_err();
        assert_eq!(e.to_string(), "reading cfg");
        assert!(format!("{e:?}").contains("no such file"));
    }

    #[test]
    fn bail_short_circuits() {
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative input {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(-1).unwrap_err().to_string().contains("negative"));
    }
}
