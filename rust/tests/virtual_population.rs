//! Virtual-population contract tests: a `population: virtual` job must be
//! bitwise-identical to the eager scaffold — same cohorts, same shards, same
//! RNG streams, same adversary draws, same churn — for any fleet size and
//! any parallelism. These tests enforce that contract at two levels: whole
//! runs (per-round metrics compared bit for bit) and the scaffold itself
//! (per-client state compared after lazy materialization).

use std::collections::BTreeSet;
use std::sync::Arc;

use flsim::config::job::{JobConfig, PopulationMode};
use flsim::config::{AttackKind, ChurnConfig};
use flsim::controller::sync::FaultPlan;
use flsim::metrics::report::RunReport;
use flsim::orchestrator::{JobState, Orchestrator, RunOptions};
use flsim::runtime::pjrt::Runtime;

fn rt() -> Arc<Runtime> {
    Runtime::shared("artifacts").unwrap()
}

/// A small job that is valid in both population modes.
fn base_job(n_clients: usize) -> JobConfig {
    let mut j = JobConfig::scale_logreg(n_clients);
    j.dataset.n = 600;
    j.rounds = 3;
    j.client_fraction = 0.5;
    j
}

/// Compare every deterministic per-round metric bit for bit. Host-dependent
/// columns (wall_secs, cpu_pct, rss_mib) are excluded by design.
fn assert_reports_identical(eager: &RunReport, virt: &RunReport, tag: &str) {
    assert_eq!(eager.n_clients, virt.n_clients, "{tag}: fleet size");
    assert_eq!(eager.rounds.len(), virt.rounds.len(), "{tag}: round count");
    for (e, v) in eager.rounds.iter().zip(&virt.rounds) {
        let r = e.round;
        assert_eq!(e.model_hash, v.model_hash, "{tag}: model hash, round {r}");
        assert_eq!(e.net_bytes, v.net_bytes, "{tag}: net bytes, round {r}");
        for (col, a, b) in [
            ("train_loss", e.train_loss, v.train_loss),
            ("test_loss", e.test_loss, v.test_loss),
            ("test_accuracy", e.test_accuracy, v.test_accuracy),
            ("sim_net_secs", e.sim_net_secs, v.sim_net_secs),
            ("sim_round_secs", e.sim_round_secs, v.sim_round_secs),
        ] {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{tag}: {col} diverged in round {r} ({a} vs {b})"
            );
        }
    }
}

fn run_both_modes(mut job: JobConfig, tag: &str) {
    job.population = PopulationMode::Eager;
    let eager = Orchestrator::new(rt()).run(&job, RunOptions::default()).unwrap();
    job.population = PopulationMode::Virtual;
    let virt = Orchestrator::new(rt()).run(&job, RunOptions::default()).unwrap();
    assert_reports_identical(&eager, &virt, tag);
}

#[test]
fn virtual_run_matches_eager_plain_fedavg() {
    run_both_modes(base_job(10), "plain");
}

#[test]
fn virtual_run_matches_eager_under_churn_and_heterogeneity() {
    let mut job = base_job(12);
    job.name = "virt_churn".into();
    job.heterogeneity = 0.3;
    job.faults.churn = Some(ChurnConfig {
        availability: 0.9,
        from_round: 1,
    });
    run_both_modes(job, "churn+hetero");
}

#[test]
fn virtual_run_matches_eager_with_label_flip_adversaries() {
    let mut job = base_job(8);
    job.name = "virt_adv".into();
    job.adversary.attack = AttackKind::LabelFlip;
    job.adversary.attack_fraction = 0.25;
    run_both_modes(job, "label_flip");
}

#[test]
fn virtual_run_is_parallelism_invariant() {
    let mut golden: Option<RunReport> = None;
    for par in [1usize, 4] {
        let mut job = base_job(10);
        job.name = format!("virt_par{par}");
        job.population = PopulationMode::Virtual;
        job.parallelism = par;
        let report = Orchestrator::new(rt()).run(&job, RunOptions::default()).unwrap();
        match &golden {
            None => golden = Some(report),
            Some(g) => assert_reports_identical(g, &report, "parallelism"),
        }
    }
}

/// Property test over random-ish configs: lazily materializing *every*
/// client of a virtual scaffold reproduces the eager scaffold's per-client
/// state exactly — shard size, speed draw, adversary membership — and the
/// fault plans agree on liveness for every (client, round) pair.
#[test]
fn lazy_materialization_matches_eager_scaffold() {
    for seed in [1u64, 2, 3] {
        for n in [7usize, 23, 41] {
            let mut job = base_job(n);
            job.name = format!("virt_prop_s{seed}_n{n}");
            job.seed = seed;
            job.heterogeneity = 0.5;
            job.adversary.attack = AttackKind::LabelFlip;
            job.adversary.attack_fraction = 0.3;
            job.faults.churn = Some(ChurnConfig {
                availability: 0.8,
                from_round: 1,
            });

            job.population = PopulationMode::Eager;
            let eager = JobState::scaffold(rt(), &job, FaultPlan::none()).unwrap();
            job.population = PopulationMode::Virtual;
            let mut virt = JobState::scaffold(rt(), &job, FaultPlan::none()).unwrap();

            let names: Vec<String> = eager.clients.keys().cloned().collect();
            assert_eq!(names.len(), n, "eager fleet size");
            assert!(virt.clients.is_empty(), "virtual fleet starts empty");
            virt.ensure_cohort(&names).unwrap();

            assert_eq!(
                eager.adversaries, virt.adversaries,
                "seed {seed} n {n}: adversary draw diverged"
            );
            for name in &names {
                let e = &eager.clients[name];
                let v = &virt.clients[name];
                assert_eq!(
                    e.n_examples, v.n_examples,
                    "seed {seed} n {n}: shard size of {name}"
                );
                assert_eq!(
                    e.speed_factor.to_bits(),
                    v.speed_factor.to_bits(),
                    "seed {seed} n {n}: speed draw of {name}"
                );
            }
            // Churn liveness must agree lazily vs densely for the whole grid.
            for name in &names {
                for round in 0..=job.rounds {
                    assert_eq!(
                        eager.controller.is_alive(name, round),
                        virt.controller.is_alive(name, round),
                        "seed {seed} n {n}: liveness of {name} in round {round}"
                    );
                }
            }
            // No cross-round strategy state on fedavg: eviction returns the
            // fleet to zero residency.
            virt.evict_cohort();
            assert!(
                virt.clients.is_empty(),
                "seed {seed} n {n}: eviction left stateless clients resident"
            );
        }
    }
}

/// Strategies that carry cross-round client state must keep those nodes
/// resident through eviction — that state is part of the result.
#[test]
fn eviction_keeps_stateful_clients_resident() {
    // SCAFFOLD needs its control-variate artifact — cnn carries it.
    let mut job = JobConfig::default_cnn("scaffold");
    job.name = "virt_scaffold_state".into();
    job.n_clients = 6;
    job.dataset.n = 600;
    job.population = PopulationMode::Virtual;
    job.client_fraction = 1.0;
    job.rounds = 2;
    let report = Orchestrator::new(rt()).run(&job, RunOptions::default()).unwrap();
    assert_eq!(report.rounds.len(), 2);

    // And the eager twin agrees bitwise even though its fleet never evicts.
    job.population = PopulationMode::Eager;
    let eager = Orchestrator::new(rt()).run(&job, RunOptions::default()).unwrap();
    assert_reports_identical(&eager, &report, "scaffold strategy");
}

/// The virtual sampler must hand the round flows the exact cohort the eager
/// sampler would draw — same names, same order.
#[test]
fn virtual_sampler_draws_the_eager_cohort() {
    let mut job = base_job(31);
    job.name = "virt_sampler".into();
    job.client_fraction = 0.2;
    job.population = PopulationMode::Eager;
    let eager = JobState::scaffold(rt(), &job, FaultPlan::none()).unwrap();
    job.population = PopulationMode::Virtual;
    let virt = JobState::scaffold(rt(), &job, FaultPlan::none()).unwrap();
    for round in 0..5u64 {
        assert_eq!(
            eager.sample_clients(round),
            virt.sample_clients(round),
            "cohort diverged in round {round}"
        );
    }
    // Distinct rounds draw distinct cohorts (sanity that sampling is live).
    let all: BTreeSet<Vec<String>> = (0..5).map(|r| virt.sample_clients(r)).collect();
    assert!(all.len() > 1, "sampler drew the same cohort every round");
}
