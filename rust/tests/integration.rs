//! Integration tests over the full stack: AOT artifacts -> PJRT runtime ->
//! orchestrated FL rounds. Requires `make artifacts` to have run (the
//! Makefile's `test` target guarantees it).

use flsim::config::job::JobConfig;
use flsim::controller::sync::FaultPlan;
use flsim::data::dataset::DatasetSpec;
use flsim::orchestrator::{Orchestrator, RunOptions};
use flsim::runtime::pjrt::Runtime;
use flsim::topology::TopologyKind;

fn artifacts_dir() -> String {
    // cargo test runs from the workspace root.
    "artifacts".to_string()
}

fn mini_job(strategy: &str) -> JobConfig {
    let mut j = JobConfig::default_cnn(strategy);
    j.rounds = 2;
    j.dataset.n = 600;
    j
}

#[test]
fn manifest_loads_and_declares_all_backends() {
    let rt = Runtime::shared(artifacts_dir()).unwrap();
    for b in ["cnn", "cnn_v2", "mlp", "logreg"] {
        let desc = rt.manifest.backend(b).unwrap();
        assert!(desc.param_count > 0);
        assert!(desc.artifacts.contains_key("sgd"));
    }
    // The Fig 8 strategies need the full artifact set on cnn.
    let cnn = rt.manifest.backend("cnn").unwrap();
    for step in ["init", "sgd", "eval", "prox", "scaffold", "moon"] {
        assert!(cnn.artifacts.contains_key(step), "cnn missing {step}");
    }
}

#[test]
fn fedavg_end_to_end_learns_and_meters() {
    let rt = Runtime::shared(artifacts_dir()).unwrap();
    let mut job = mini_job("fedavg");
    job.rounds = 4;
    job.dataset.n = 1200;
    let report = Orchestrator::new(rt).run(&job, RunOptions::default()).unwrap();
    assert_eq!(report.rounds.len(), 4);
    // Loss must drop over 4 rounds on the synthetic set.
    assert!(report.rounds[3].test_loss < report.rounds[0].test_loss);
    // Traffic metered every round; model hash recorded.
    for r in &report.rounds {
        assert!(r.net_bytes > 0);
        assert_eq!(r.model_hash.len(), 16);
        assert!(r.wall_secs > 0.0);
    }
}

#[test]
fn same_seed_is_bitwise_reproducible() {
    let rt = Runtime::shared(artifacts_dir()).unwrap();
    let orch = Orchestrator::new(rt);
    let job = mini_job("fedavg");
    let a = orch.run(&job, RunOptions::default()).unwrap();
    let b = orch.run(&job, RunOptions::default()).unwrap();
    for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(ra.model_hash, rb.model_hash, "round {}", ra.round);
        assert_eq!(ra.test_accuracy, rb.test_accuracy);
        assert_eq!(ra.net_bytes, rb.net_bytes);
    }
}

#[test]
fn different_seed_changes_trajectory() {
    let rt = Runtime::shared(artifacts_dir()).unwrap();
    let orch = Orchestrator::new(rt);
    let mut j1 = mini_job("fedavg");
    let mut j2 = mini_job("fedavg");
    j1.seed = 1;
    j2.seed = 2;
    let a = orch.run(&j1, RunOptions::default()).unwrap();
    let b = orch.run(&j2, RunOptions::default()).unwrap();
    assert_ne!(a.rounds[0].model_hash, b.rounds[0].model_hash);
}

#[test]
fn scaffold_moves_extra_state_over_the_wire() {
    let rt = Runtime::shared(artifacts_dir()).unwrap();
    let orch = Orchestrator::new(rt);
    let fedavg = orch.run(&mini_job("fedavg"), RunOptions::default()).unwrap();
    let scaffold = orch.run(&mini_job("scaffold"), RunOptions::default()).unwrap();
    // Control variates ≈ double the client upload volume.
    assert!(
        scaffold.total_net_bytes() > fedavg.total_net_bytes() * 4 / 3,
        "scaffold {} vs fedavg {}",
        scaffold.total_net_bytes(),
        fedavg.total_net_bytes()
    );
}

#[test]
fn multi_worker_consensus_defeats_malicious_worker() {
    let rt = Runtime::shared(artifacts_dir()).unwrap();
    let orch = Orchestrator::new(rt);
    let mut job = mini_job("fedavg");
    job.rounds = 3;
    job.dataset.n = 1200;
    job.n_workers = 3;
    job.consensus.malicious_workers = vec!["worker_0".into()];
    let poisoned_guarded = orch.run(&job, RunOptions::default()).unwrap();

    let mut solo = job.clone();
    solo.n_workers = 1; // the only worker is malicious -> training destroyed
    let destroyed = orch.run(&solo, RunOptions::default()).unwrap();

    assert!(
        poisoned_guarded.final_accuracy() > destroyed.final_accuracy(),
        "consensus {} <= poisoned {}",
        poisoned_guarded.final_accuracy(),
        destroyed.final_accuracy()
    );
}

#[test]
fn hierarchical_topology_runs_and_costs_more_bandwidth() {
    let rt = Runtime::shared(artifacts_dir()).unwrap();
    let orch = Orchestrator::new(rt);
    let flat = orch.run(&mini_job("fedavg"), RunOptions::default()).unwrap();

    let mut job = mini_job("fedavg");
    job.topology = TopologyKind::Hierarchical;
    job.n_workers = 3;
    let hier = orch.run(&job, RunOptions::default()).unwrap();
    assert_eq!(hier.rounds.len(), 2);
    assert!(hier.total_net_bytes() > flat.total_net_bytes());
}

#[test]
fn decentralized_flow_runs_with_ring_and_mesh() {
    let rt = Runtime::shared(artifacts_dir()).unwrap();
    let orch = Orchestrator::new(rt);
    let mut mesh = mini_job("fedstellar");
    mesh.n_clients = 5;
    let mesh_report = orch.run(&mesh, RunOptions::default()).unwrap();

    let mut ring = mesh.clone();
    ring.topology = TopologyKind::Ring;
    let ring_report = orch.run(&ring, RunOptions::default()).unwrap();
    assert!(mesh_report.total_net_bytes() > ring_report.total_net_bytes());
}

#[test]
fn decentralized_strategy_rejects_star_topology() {
    let rt = Runtime::shared(artifacts_dir()).unwrap();
    let mut job = mini_job("fedstellar");
    job.topology = TopologyKind::ClientServer;
    assert!(Orchestrator::new(rt).run(&job, RunOptions::default()).is_err());
}

#[test]
fn fault_injection_survives_client_drop() {
    let rt = Runtime::shared(artifacts_dir()).unwrap();
    let orch = Orchestrator::new(rt);
    let mut job = mini_job("fedavg");
    job.rounds = 3;
    let faults = FaultPlan::none()
        .drop_in_round("client_2", 2)
        .crash_from("client_7", 3);
    let report = orch.run(&job, RunOptions::default().faults(faults)).unwrap();
    assert_eq!(report.rounds.len(), 3);
}

#[test]
fn bcfl_on_chain_consensus_roundtrip() {
    let rt = Runtime::shared(artifacts_dir()).unwrap();
    let orch = Orchestrator::new(rt);
    for platform in ["ethereum", "fabric"] {
        let mut job = mini_job("fedavg");
        job.n_workers = 3;
        job.consensus.on_chain = true;
        job.consensus.malicious_workers = vec!["worker_0".into()];
        job.chain.enabled = true;
        job.chain.platform = platform.into();
        let report = Orchestrator::new(
            Runtime::shared(artifacts_dir()).unwrap(),
        )
        .run(&job, RunOptions::default())
        .unwrap();
        assert_eq!(report.rounds.len(), 2, "{platform}");
        let _ = &orch;
    }
}

#[test]
fn library_agnostic_backends_run_same_job() {
    let rt = Runtime::shared(artifacts_dir()).unwrap();
    let orch = Orchestrator::new(rt);
    for backend in ["cnn", "cnn_v2", "mlp"] {
        let mut job = mini_job("fedavg");
        job.backend = backend.into();
        job.rounds = 1;
        let report = orch.run(&job, RunOptions::default()).unwrap();
        assert_eq!(report.rounds.len(), 1, "{backend}");
    }
    // logreg with the MNIST-shaped dataset.
    let mut job = mini_job("fedavg");
    job.backend = "logreg".into();
    job.dataset = DatasetSpec::mnist_iid(600);
    job.rounds = 1;
    let report = orch.run(&job, RunOptions::default()).unwrap();
    assert_eq!(report.rounds.len(), 1);
}

#[test]
fn strategy_missing_artifact_fails_cleanly() {
    let rt = Runtime::shared(artifacts_dir()).unwrap();
    // mlp has no moon artifact — must error with a helpful message, not panic.
    let mut job = mini_job("moon");
    job.backend = "mlp".into();
    let err = Orchestrator::new(rt).run(&job, RunOptions::default()).unwrap_err().to_string();
    assert!(err.contains("moon"), "unhelpful error: {err}");
}

#[test]
fn yaml_config_to_run_pipeline() {
    let yaml = r#"
job: {name: itest, seed: 5, rounds: 2}
dataset:
  name: cifar10_synth
  n: 600
  distribution: {kind: dirichlet, alpha: 0.5}
strategy:
  name: fedavg
  backend: cnn
  train_params: {learning_rate: 0.02, local_epochs: 2}
topology: {kind: client_server, clients: 4, workers: 1}
"#;
    let job = JobConfig::from_yaml_str(yaml).unwrap();
    let rt = Runtime::shared(artifacts_dir()).unwrap();
    let report = Orchestrator::new(rt).run(&job, RunOptions::default()).unwrap();
    assert_eq!(report.rounds.len(), 2);
    assert_eq!(report.n_clients, 4);
}

#[test]
fn hw_profiles_reproduce_within_and_drift_across() {
    let rt = Runtime::shared(artifacts_dir()).unwrap();
    let orch = Orchestrator::new(rt);
    use flsim::aggregate::mean::ReductionOrder;
    let mut base = mini_job("fedavg");
    base.rounds = 2;
    base.n_clients = 7; // odd count tickles reduction-order differences

    let mut hashes = Vec::new();
    for order in ReductionOrder::ALL {
        let mut j = base.clone();
        j.hw_profile = order;
        let a = orch.run(&j, RunOptions::default()).unwrap();
        let b = orch.run(&j, RunOptions::default()).unwrap();
        assert_eq!(
            a.rounds.last().unwrap().model_hash,
            b.rounds.last().unwrap().model_hash,
            "{order:?} not reproducible"
        );
        hashes.push(a.rounds.last().unwrap().model_hash.clone());
        // Accuracy must stay in a tight band across profiles.
        assert!((a.final_accuracy() - 0.5).abs() < 0.5);
    }
    // At least one profile must differ bitwise from Sequential.
    assert!(
        hashes[1..].iter().any(|h| *h != hashes[0]),
        "all reduction orders produced identical bits — profile simulation inert"
    );
}
