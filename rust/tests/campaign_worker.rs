//! Distributed-worker contracts (the acceptance criteria of the
//! lease-based campaign-worker PR):
//!
//! * two concurrent workers drain one store with **zero duplicate
//!   executions** — every cell is executed by exactly one of them, and the
//!   drained store serves the campaign report entirely from cache;
//! * SIGKILLing a worker mid-cell loses no committed work: the survivor
//!   reclaims the expired lease, finishes the campaign, and the final
//!   report is byte-stable and bitwise-identical (modulo wall clocks) to a
//!   single-process run;
//! * a rung-stopped ASHA cell resumes from its checkpointed rung instead
//!   of round 1 — strictly fewer engine executions, identical bits — and
//!   completing the cell removes the checkpoint blob;
//! * ASHA promotions are **elastic-deterministic**: the promoted set and
//!   every per-round metric are invariant to worker count ∈ {1, 2, 4} and
//!   equal to the in-process scheduler's (property-tested over sampled
//!   specs, mirroring `rust/tests/proptests.rs`'s hand-rolled harness).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use flsim::campaign::{
    self, lease, CampaignOutcome, CampaignReport, CampaignSpec, ResultStore, SchedulerSpec,
    WorkerOptions,
};
use flsim::config::job::JobConfig;
use flsim::controller::FaultPlan;
use flsim::metrics::report::RoundMetrics;
use flsim::orchestrator::{Orchestrator, RunControl, RunHandle, RunOptions};
use flsim::runtime::pjrt::Runtime;
use flsim::util::yaml::Yaml;

fn tmp_store(tag: &str) -> (ResultStore, PathBuf) {
    let dir = std::env::temp_dir().join(format!(
        "flsim_worker_{tag}_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    (ResultStore::open(&dir).unwrap(), dir)
}

fn tiny_base() -> JobConfig {
    let mut j = JobConfig::default_cnn("fedavg");
    j.name = "tiny".into();
    j.rounds = 2;
    j.dataset.n = 600;
    j.n_clients = 4;
    j
}

/// In-process workers never crash, so a long expiry makes lease stealing
/// impossible — any duplicate execution the tests observe is a real
/// protocol bug, not an expiry race.
fn fast_opts(owner: &str) -> WorkerOptions {
    let mut o = WorkerOptions::new(owner);
    o.lease.heartbeat = Duration::from_millis(100);
    o.lease.expiry = Duration::from_secs(60);
    o.poll = Duration::from_millis(10);
    o
}

/// Run `n` cooperative workers (threads, one shared store) to completion.
fn drain_n(
    rt: &Arc<Runtime>,
    spec: &CampaignSpec,
    store: &ResultStore,
    n: usize,
) -> Vec<CampaignOutcome> {
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..n)
            .map(|w| {
                let rt = rt.clone();
                let opts = fast_opts(&format!("w{w}"));
                s.spawn(move || campaign::drain(rt, spec, store, &opts))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("worker thread panicked").unwrap())
            .collect()
    })
}

/// Every per-round field two runs must agree on bitwise — everything except
/// the wall clocks (`wall_secs`, `cpu_pct`, `rss_mib`), which belong to
/// whichever process happened to execute the cell.
fn assert_rounds_bitwise_equal(a: &[RoundMetrics], b: &[RoundMetrics], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: round count");
    for (ma, mb) in a.iter().zip(b) {
        let r = ma.round;
        assert_eq!(ma.round, mb.round, "{what}");
        assert_eq!(ma.model_hash, mb.model_hash, "{what} round {r}");
        assert_eq!(ma.net_bytes, mb.net_bytes, "{what} round {r}");
        assert_eq!(ma.test_accuracy.to_bits(), mb.test_accuracy.to_bits(), "{what} round {r}");
        assert_eq!(ma.test_loss.to_bits(), mb.test_loss.to_bits(), "{what} round {r}");
        assert_eq!(ma.train_loss.to_bits(), mb.train_loss.to_bits(), "{what} round {r}");
        assert_eq!(ma.sim_round_secs.to_bits(), mb.sim_round_secs.to_bits(), "{what} round {r}");
    }
}

// ---------------------------------------------------------------------------
// Two cooperative workers: disjoint execution, complete store.
// ---------------------------------------------------------------------------

#[test]
fn two_workers_drain_disjointly_and_the_store_serves_the_report() {
    let (store, dir) = tmp_store("pair");
    let rt = Runtime::shared("artifacts").unwrap();
    let spec = CampaignSpec::builder("pair", tiny_base())
        .axis_strs("strategy", &["fedavg", "fedprox"])
        .axis_ints("seed", &[1, 2])
        .build();

    let outcomes = drain_n(&rt, &spec, &store, 2);
    let (a, b) = (&outcomes[0], &outcomes[1]);
    for o in [a, b] {
        assert!(o.failed().is_empty(), "{:?}", o.failure_lines());
        assert_eq!(o.cells.len(), 4);
        assert!(o.cells.iter().all(|c| c.report.is_some()));
    }

    // Zero duplicate executions: each cell was executed by exactly one
    // worker (`cached == false` marks "this drain executed it"); both
    // workers agree on the bits regardless of who ran what.
    for (ca, cb) in a.cells.iter().zip(&b.cells) {
        assert_eq!(ca.cell.key, cb.cell.key);
        assert!(
            !ca.cached ^ !cb.cached,
            "cell {} executed by {} workers",
            ca.cell.name,
            [ca, cb].iter().filter(|c| !c.cached).count()
        );
        assert_rounds_bitwise_equal(
            &ca.report.as_ref().unwrap().rounds,
            &cb.report.as_ref().unwrap().rounds,
            &ca.cell.name,
        );
    }
    assert!(lease::live(store.dir(), Duration::from_secs(60)).is_empty());

    // The drained store serves the whole campaign from cache — zero engine
    // executions — and matches a single-process run bit for bit.
    let execs = rt.stats().executions;
    let replay = campaign::run(rt.clone(), &spec, &store).unwrap();
    assert!(replay.all_cached(), "drained store must serve every cell");
    assert_eq!(rt.stats().executions, execs);

    let (store_solo, dir_solo) = tmp_store("pair_solo");
    let solo = campaign::run(rt, &spec, &store_solo).unwrap();
    for (w, s) in replay.cells.iter().zip(&solo.cells) {
        assert_rounds_bitwise_equal(
            &w.report.as_ref().unwrap().rounds,
            &s.report.as_ref().unwrap().rounds,
            &w.cell.name,
        );
    }

    std::fs::remove_dir_all(&dir).unwrap();
    std::fs::remove_dir_all(&dir_solo).unwrap();
}

// ---------------------------------------------------------------------------
// Crash recovery: SIGKILL one of two worker processes mid-cell.
// ---------------------------------------------------------------------------

/// Four-cell grid (seed sweep) with enough rounds that the kill lands
/// mid-cell. `parallelism: 1` keeps each worker process single-threaded.
const KILL_SPEC: &str = r#"
campaign:
  name: killtest
axes:
  seed: [1, 2, 3, 4]
job:
  name: kt
  rounds: 6
  parallelism: 1
dataset:
  name: cifar10_synth
  n: 600
  distribution:
    kind: dirichlet
    alpha: 0.5
strategy:
  name: fedavg
  backend: cnn
  train_params:
    learning_rate: 0.01
    local_epochs: 2
topology:
  kind: client_server
  clients: 4
  workers: 1
"#;

#[test]
fn killed_worker_is_reclaimed_and_loses_no_committed_work() {
    let base = std::env::temp_dir().join(format!("flsim_worker_kill_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    std::fs::create_dir_all(&base).unwrap();
    let store_dir = base.join("store");
    let spec_path = base.join("kill.yaml");
    std::fs::write(&spec_path, KILL_SPEC).unwrap();

    let spawn = |owner: &str| {
        std::process::Command::new(env!("CARGO_BIN_EXE_flsim"))
            .args([
                "campaign",
                "worker",
                store_dir.to_str().unwrap(),
                spec_path.to_str().unwrap(),
                "--owner",
                owner,
                "--heartbeat-secs",
                "0.1",
                "--expiry-secs",
                "1.0",
                "--poll-secs",
                "0.1",
            ])
            .stdout(std::process::Stdio::piped())
            .stderr(std::process::Stdio::piped())
            .spawn()
            .expect("spawning flsim campaign worker")
    };

    // Worker 1 starts draining; SIGKILL it the moment it holds a lease —
    // mid-cell, heartbeat thread and all.
    let mut w1 = spawn("w1");
    let lease_dir = store_dir.join("leases");
    let deadline = std::time::Instant::now() + Duration::from_secs(120);
    loop {
        let leased = std::fs::read_dir(&lease_dir)
            .map(|d| {
                d.flatten()
                    .any(|f| f.path().extension().map(|e| e == "lease").unwrap_or(false))
            })
            .unwrap_or(false);
        if leased {
            break;
        }
        if let Some(status) = w1.try_wait().unwrap() {
            panic!("worker 1 exited before leasing anything: {status}");
        }
        assert!(
            std::time::Instant::now() < deadline,
            "worker 1 never leased a cell"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    w1.kill().unwrap(); // SIGKILL on unix — no Drop, no lease release
    w1.wait().unwrap();

    // The survivor reclaims the orphaned lease after the 1s expiry and
    // finishes the campaign alone.
    let w2 = spawn("w2");
    let out = w2.wait_with_output().unwrap();
    assert!(
        out.status.success(),
        "worker 2 failed:\n{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );

    // Every cell is complete in the store; no live lease remains.
    let spec = CampaignSpec::from_yaml_file(spec_path.to_str().unwrap()).unwrap();
    let store = ResultStore::open(&store_dir).unwrap();
    for c in campaign::expand(&spec).unwrap() {
        assert!(
            store.get(&c.key).is_some(),
            "cell {} missing after the two-worker drain",
            c.name
        );
    }
    assert!(
        lease::live(store.dir(), Duration::from_secs(60)).is_empty(),
        "live lease left behind after drain"
    );

    // The drained store serves the campaign entirely from cache, and the
    // report it yields is byte-identical across generations.
    let rt = Runtime::shared("artifacts").unwrap();
    let execs = rt.stats().executions;
    let first = campaign::run(rt.clone(), &spec, &store).unwrap();
    assert!(first.all_cached(), "drained store must serve every cell");
    assert_eq!(rt.stats().executions, execs, "replay must not touch the engine");
    let second = campaign::run(rt.clone(), &spec, &store).unwrap();
    assert_eq!(
        CampaignReport::from_outcome(&first).to_csv(),
        CampaignReport::from_outcome(&second).to_csv()
    );
    assert_eq!(
        CampaignReport::from_outcome(&first).to_json().to_string(),
        CampaignReport::from_outcome(&second).to_json().to_string()
    );

    // And the surviving bits are the single-process run's, exactly.
    let (store_solo, dir_solo) = tmp_store("kill_solo");
    let solo = campaign::run(rt, &spec, &store_solo).unwrap();
    for (w, s) in first.cells.iter().zip(&solo.cells) {
        assert_rounds_bitwise_equal(
            &w.report.as_ref().unwrap().rounds,
            &s.report.as_ref().unwrap().rounds,
            &w.cell.name,
        );
    }

    std::fs::remove_dir_all(&base).unwrap();
    std::fs::remove_dir_all(&dir_solo).unwrap();
}

// ---------------------------------------------------------------------------
// Checkpointed rung promotion: resume from the rung, not from round 1.
// ---------------------------------------------------------------------------

/// The campaign.rs eight-cell ASHA sweep (2×2×2, 4 rounds, rungs 1/2/4) —
/// every cell checkpointable (fedavg/fedprox, client-server, eager).
fn eight_cell_asha() -> CampaignSpec {
    let mut base = tiny_base();
    base.name = "asha8".into();
    base.rounds = 4;
    CampaignSpec::builder("asha8", base)
        .axis_strs("strategy", &["fedavg", "fedprox"])
        .axis_ints("seed", &[1, 2])
        .axis("learning_rate", vec![Yaml::Float(0.01), Yaml::Float(0.02)])
        .jobs(2)
        .asha(2, 1)
        .build()
}

#[test]
fn rung_stopped_cells_resume_from_their_checkpoints() {
    let (store, dir) = tmp_store("ckpt");
    let rt = Runtime::shared("artifacts").unwrap();
    let spec = eight_cell_asha();

    let first = campaign::run(rt.clone(), &spec, &store).unwrap();
    assert!(first.failed().is_empty(), "{:?}", first.failure_lines());
    let stopped: Vec<_> = first
        .cells
        .iter()
        .filter(|c| c.report.as_ref().unwrap().stopped_early)
        .collect();
    assert!(!stopped.is_empty());

    // Every rung-stopped cell left a checkpoint blob at its stored depth.
    for c in &stopped {
        let depth = store
            .get_at_least(&c.cell.key, 1)
            .expect("rung-stopped cell must have a partial entry")
            .rounds_completed();
        let ckpt = store
            .get_checkpoint(&c.cell.key)
            .expect("rung-stopped checkpointable cell must leave a checkpoint");
        assert_eq!(ckpt.key, c.cell.key);
        assert_eq!(ckpt.rounds, depth);
    }

    // A grid run over the same store resumes each stopped cell from its
    // checkpointed rung: strictly fewer engine executions than running the
    // same grid from scratch, identical bits.
    let mut grid_spec = spec.clone();
    grid_spec.scheduler = SchedulerSpec::default();
    let before = rt.stats().executions;
    let resumed = campaign::run(rt.clone(), &grid_spec, &store).unwrap();
    let resumed_execs = rt.stats().executions - before;
    assert!(resumed.failed().is_empty(), "{:?}", resumed.failure_lines());

    let (store_scratch, dir_scratch) = tmp_store("ckpt_scratch");
    let before = rt.stats().executions;
    let scratch = campaign::run(rt.clone(), &grid_spec, &store_scratch).unwrap();
    let scratch_execs = rt.stats().executions - before;
    assert!(
        resumed_execs < scratch_execs,
        "resume-from-checkpoint must save executions ({resumed_execs} vs {scratch_execs})"
    );
    for (a, b) in resumed.cells.iter().zip(&scratch.cells) {
        assert_rounds_bitwise_equal(
            &a.report.as_ref().unwrap().rounds,
            &b.report.as_ref().unwrap().rounds,
            &a.cell.name,
        );
    }

    // Completing a cell removes its checkpoint (complete entries supersede
    // the blob; gc would otherwise sweep it as an orphan).
    for c in &stopped {
        assert!(
            store.get_checkpoint(&c.cell.key).is_none(),
            "checkpoint for {} must be removed by the complete commit",
            c.cell.name
        );
    }

    std::fs::remove_dir_all(&dir).unwrap();
    std::fs::remove_dir_all(&dir_scratch).unwrap();
}

#[test]
fn resume_continues_bitwise_and_refuses_stateful_strategies() {
    let rt = Runtime::shared("artifacts").unwrap();
    let mut job = tiny_base();
    job.rounds = 4;

    let full = Orchestrator::new(rt.clone())
        .run(&job, RunOptions::default())
        .unwrap();

    // Pause at round 2 and capture (partial report, params) — the exact
    // payload a worker commits at a rung.
    let mut h = RunHandle::start(rt.clone(), &job, FaultPlan::none()).unwrap();
    h.advance(&RunControl::budget(2)).unwrap();
    let prefix = h.partial_report();
    let params = h.checkpoint_params().expect("fedavg/client-server is checkpointable");
    assert!(prefix.stopped_early);
    assert_eq!(prefix.rounds_completed(), 2);
    drop(h);

    // Resuming replays nothing: rounds 3 and 4 continue bitwise from the
    // checkpoint, reproducing the uninterrupted run exactly.
    let mut r = RunHandle::resume(rt.clone(), &job, FaultPlan::none(), &prefix, &params).unwrap();
    assert_eq!(r.rounds_done(), 2);
    r.advance(&RunControl::unbounded()).unwrap();
    let resumed = r.finish().unwrap();
    assert!(!resumed.stopped_early);
    assert_rounds_bitwise_equal(&resumed.rounds, &full.rounds, "checkpoint resume");

    // Strategies with cross-round state beyond the global model are not
    // checkpointable and must refuse to resume rather than resume wrongly.
    let stateful = JobConfig::default_cnn("scaffold");
    assert!(!RunHandle::checkpointable(&stateful));
    let sh = RunHandle::start(rt.clone(), &stateful, FaultPlan::none()).unwrap();
    assert!(sh.checkpoint_params().is_none());
    assert!(RunHandle::resume(rt, &stateful, FaultPlan::none(), &prefix, &params).is_err());
}

// ---------------------------------------------------------------------------
// Elastic-deterministic ASHA: promotions invariant to worker count.
// ---------------------------------------------------------------------------

/// Sampled spec variants for the worker-count property (hand-rolled
/// generator in the proptests.rs idiom — proptest is not vendored).
fn asha_variant(v: u64) -> CampaignSpec {
    let mut base = tiny_base();
    base.name = format!("asha_inv{v}");
    base.rounds = 4;
    CampaignSpec::builder(&format!("asha_inv{v}"), base)
        .axis_strs("strategy", &["fedavg", "fedprox"])
        .axis_ints("seed", &[(10 * v + 1) as i64, (10 * v + 2) as i64])
        .axis("learning_rate", vec![Yaml::Float(0.01), Yaml::Float(0.02)])
        .jobs(2)
        .asha(2, 1)
        .build()
}

#[test]
fn asha_promotions_invariant_to_worker_count() {
    let rt = Runtime::shared("artifacts").unwrap();
    for variant in 0..2u64 {
        let spec = asha_variant(variant);

        // Ground truth: the in-process scheduler on its own store.
        let (store_ref, dir_ref) = tmp_store(&format!("inv{variant}_ref"));
        let reference = campaign::run(rt.clone(), &spec, &store_ref).unwrap();
        assert!(reference.failed().is_empty(), "{:?}", reference.failure_lines());

        for &w in &[1usize, 2, 4] {
            let (store, dir) = tmp_store(&format!("inv{variant}_w{w}"));
            let outcomes = drain_n(&rt, &spec, &store, w);

            // Every worker derives the identical outcome (cached flags
            // aside — those only say who did the work).
            for o in &outcomes {
                assert!(o.failed().is_empty(), "{:?}", o.failure_lines());
                assert_eq!(o.cells.len(), reference.cells.len());
                for (c, r) in o.cells.iter().zip(&reference.cells) {
                    assert_eq!(c.cell.key, r.cell.key, "variant {variant}, {w} workers");
                    let (cr, rr) = (c.report.as_ref().unwrap(), r.report.as_ref().unwrap());
                    assert_eq!(
                        cr.stopped_early,
                        rr.stopped_early,
                        "variant {variant}: cell {} promoted under {w} workers but not \
                         by the in-process scheduler",
                        c.cell.name
                    );
                    assert_rounds_bitwise_equal(
                        &cr.rounds,
                        &rr.rounds,
                        &format!("variant {variant}, {w} workers, cell {}", c.cell.name),
                    );
                }
            }
            std::fs::remove_dir_all(&dir).unwrap();
        }
        std::fs::remove_dir_all(&dir_ref).unwrap();
    }
}
