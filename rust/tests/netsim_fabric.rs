//! The topology-aware virtual-clock fabric's acceptance suite:
//!
//! * Fig 11e transfer-time ordering — at equal model size and rounds,
//!   `sim_net_secs(fully_connected) > sim_net_secs(hierarchical) >
//!   sim_net_secs(client_server)`, because the fabric routes every delivery
//!   over the actual overlay edges instead of a flat default link.
//! * Observationality — the virtual clock (network config + heterogeneity)
//!   never changes training results until a deadline is configured.
//! * Emergent stragglers — a `round_deadline_secs`-induced drop produces
//!   the same surviving-quorum metrics as the equivalent
//!   `FaultPlan`-scripted drop.

use flsim::config::job::JobConfig;
use flsim::controller::sync::FaultPlan;
use flsim::kvstore::netsim::LinkModel;
use flsim::metrics::report::RunReport;
use flsim::orchestrator::{run_standard_round, JobState, Orchestrator, RunOptions};
use flsim::runtime::pjrt::Runtime;
use flsim::topology::TopologyKind;

fn rt() -> std::sync::Arc<Runtime> {
    Runtime::shared("artifacts").unwrap()
}

fn mini(strategy: &str) -> JobConfig {
    let mut j = JobConfig::default_cnn(strategy);
    j.rounds = 2;
    j.dataset.n = 600;
    j.n_clients = 6;
    j
}

#[test]
fn fig11e_topology_transfer_time_ordering() {
    let orch = Orchestrator::new(rt());

    let cs = orch.run(&mini("fedavg"), RunOptions::default()).unwrap();

    let mut hier_job = mini("fedavg");
    hier_job.topology = TopologyKind::Hierarchical;
    hier_job.n_workers = 3;
    let hier = orch.run(&hier_job, RunOptions::default()).unwrap();

    let fc = orch.run(&mini("fedstellar"), RunOptions::default()).unwrap();

    let (cs_t, hier_t, fc_t) = (
        cs.total_sim_net_secs(),
        hier.total_sim_net_secs(),
        fc.total_sim_net_secs(),
    );
    assert!(
        fc_t > hier_t && hier_t > cs_t,
        "Fig 11e ordering violated: fully_connected {fc_t:.3}s, \
         hierarchical {hier_t:.3}s, client_server {cs_t:.3}s"
    );
    // The virtual makespan series is populated everywhere.
    for r in [&cs, &hier, &fc] {
        for m in &r.rounds {
            assert!(m.sim_round_secs > 0.0, "{}: empty makespan", r.label);
        }
    }
    // And the makespan ranks the same way: a mesh round serializes each
    // peer's (n-1) pulls over its uplink, the star pays one round trip.
    assert!(fc.total_sim_round_secs() > cs.total_sim_round_secs());
}

#[test]
fn virtual_clock_is_observational_without_a_deadline() {
    let orch = Orchestrator::new(rt());
    let plain = orch.run(&mini("fedavg"), RunOptions::default()).unwrap();

    // Same job with a radically different fabric: slow uplinks, a 3x
    // compute spread — but no deadline. Every training result must be
    // bitwise identical; only the simulated times may move.
    let mut fabric_job = mini("fedavg");
    fabric_job.heterogeneity = 3.0;
    fabric_job.network.edge = LinkModel {
        latency_ms: 500.0,
        bandwidth_mbps: 0.25,
    };
    let fabric = orch.run(&fabric_job, RunOptions::default()).unwrap();

    assert_eq!(plain.rounds.len(), fabric.rounds.len());
    for (a, b) in plain.rounds.iter().zip(&fabric.rounds) {
        assert_eq!(a.model_hash, b.model_hash, "round {}", a.round);
        assert_eq!(a.test_accuracy.to_bits(), b.test_accuracy.to_bits());
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
        assert_eq!(a.net_bytes, b.net_bytes);
        // The fabric *did* slow the virtual clock down.
        assert!(b.sim_round_secs > a.sim_round_secs, "round {}", a.round);
    }
}

#[test]
fn heterogeneity_profiles_are_deterministic_and_spread() {
    let job = {
        let mut j = mini("fedavg");
        j.heterogeneity = 1.0;
        j.rounds = 1;
        j
    };
    let mut s1 = JobState::scaffold(rt(), &job, FaultPlan::none()).unwrap();
    let mut s2 = JobState::scaffold(rt(), &job, FaultPlan::none()).unwrap();
    let _ = run_standard_round(&mut s1, 1).unwrap();
    let _ = run_standard_round(&mut s2, 1).unwrap();
    assert!(!s1.client_virtual_secs.is_empty());
    // Same seed => identical per-client virtual finishes.
    for (name, secs) in &s1.client_virtual_secs {
        assert_eq!(
            secs.to_bits(),
            s2.client_virtual_secs[name].to_bits(),
            "{name} virtual time not reproducible"
        );
    }
    // heterogeneity > 0 actually spreads the fleet.
    let times: Vec<f64> = s1.client_virtual_secs.values().copied().collect();
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = times.iter().cloned().fold(0.0f64, f64::max);
    assert!(max > min, "no spread across clients ({min} .. {max})");
}

/// Find the slowest client's virtual finish and the runner-up's, so a
/// deadline can be pinned between them.
fn straggler_cutoff(job: &JobConfig) -> (String, f64) {
    let mut probe = JobState::scaffold(rt(), job, FaultPlan::none()).unwrap();
    let _ = run_standard_round(&mut probe, 1).unwrap();
    let mut finishes: Vec<(String, f64)> = probe
        .client_virtual_secs
        .iter()
        .map(|(k, v)| (k.clone(), *v))
        .collect();
    finishes.sort_by(|a, b| a.1.total_cmp(&b.1));
    let slowest = finishes.last().unwrap().clone();
    let runner_up = finishes[finishes.len() - 2].1;
    assert!(
        slowest.1 > runner_up,
        "need a unique straggler to cut ({} vs {})",
        slowest.1,
        runner_up
    );
    (slowest.0, (runner_up + slowest.1) / 2.0)
}

#[test]
fn deadline_straggler_drop_matches_fault_plan_drop() {
    let mut base = mini("fedavg");
    base.rounds = 3;
    base.heterogeneity = 2.0;

    let (straggler, deadline) = straggler_cutoff(&base);

    // Emergent drop: the deadline cuts the straggler every round.
    let mut deadline_job = base.clone();
    deadline_job.round_deadline_secs = Some(deadline);
    let emergent = Orchestrator::new(rt()).run(&deadline_job, RunOptions::default()).unwrap();

    // Scripted drop: the equivalent FaultPlan crash (same client, every
    // round). The surviving quorum must produce identical training metrics.
    let scripted: RunReport = Orchestrator::new(rt())
        .run(
            &base,
            RunOptions::default().faults(FaultPlan::none().crash_from(&straggler, 1)),
        )
        .unwrap();

    assert_eq!(emergent.rounds.len(), scripted.rounds.len());
    for (e, s) in emergent.rounds.iter().zip(&scripted.rounds) {
        assert_eq!(
            e.model_hash, s.model_hash,
            "round {}: emergent straggler drop diverged from scripted drop",
            e.round
        );
        assert_eq!(e.test_accuracy.to_bits(), s.test_accuracy.to_bits());
        assert_eq!(e.train_loss.to_bits(), s.train_loss.to_bits());
    }
}

#[test]
fn deadline_straggler_is_reported_late_not_faulted() {
    let mut job = mini("fedavg");
    job.rounds = 1;
    job.heterogeneity = 2.0;
    let (straggler, deadline) = straggler_cutoff(&job);
    job.round_deadline_secs = Some(deadline);

    let mut state = JobState::scaffold(rt(), &job, FaultPlan::none()).unwrap();
    let m = run_standard_round(&mut state, 1).unwrap();
    // Dropped through the barrier's timeout arm...
    assert!(state.controller.is_late(&straggler, 1));
    assert!(state
        .controller
        .emitted
        .iter()
        .any(|l| l.contains("timeout()")));
    // ...the round advanced at the deadline...
    assert!(m.sim_round_secs >= deadline);
    // ...and the straggler's recorded finish genuinely overran it.
    assert!(state.client_virtual_secs[&straggler] > deadline);
}
