//! Bitwise pin of the SIMD-blocked aggregation kernels against scalar
//! reference reductions.
//!
//! The blocked kernels (`aggregate::kernel`, 8-lane fixed-width blocks +
//! scalar tail) vectorize the *element* axis only, so each output element's
//! floating-point operation sequence is exactly what its `ReductionOrder`
//! defines — blocking must never move a bit. This test re-implements every
//! reduction order as straight-line scalar code (no blocking, no chunking,
//! no threads) and asserts `weighted_mean_plan` and `StreamingMean`
//! reproduce it bit for bit across:
//!
//! * all 4 reduction orders (the simulated hardware profiles),
//! * parallelism 1 / 4 / 8 (block-parallel chunking engaged on the large
//!   dim, inline on the small ones),
//! * dims deliberately NOT multiples of the 8-lane block width, so the
//!   scalar tail path is always exercised (13, 127, CHUNK+37, 32·CHUNK+5).

use flsim::aggregate::kernel::LANES;
use flsim::aggregate::mean::{weighted_mean_plan, AggPlan, ReductionOrder, StreamingMean};
use flsim::util::rng::Rng;

/// Element chunk size of the plan executor (mirrors aggregate::mean::CHUNK).
const CHUNK: usize = 4096;

fn random_models(seed: u64, n: usize, dim: usize) -> (Vec<Vec<f32>>, Vec<f64>) {
    let mut rng = Rng::seed_from(seed);
    let params: Vec<Vec<f32>> = (0..n)
        .map(|_| (0..dim).map(|_| rng.normal_f32() * 3.0).collect())
        .collect();
    let weights: Vec<f64> = (0..n).map(|i| 1.0 + (i % 7) as f64).collect();
    (params, weights)
}

/// Per-element scalar pairwise tree: split at the largest power of two
/// strictly below n, left + right — the association `pairwise_into` uses.
fn scalar_pairwise_elem(params: &[&[f32]], w: &[f32], mlo: usize, mhi: usize, j: usize) -> f32 {
    let n = mhi - mlo;
    if n == 1 {
        return w[mlo] * params[mlo][j];
    }
    let split = 1usize << (n - 1).ilog2();
    let left = scalar_pairwise_elem(params, w, mlo, mlo + split, j);
    let right = scalar_pairwise_elem(params, w, mlo + split, mhi, j);
    left + right
}

/// Straight-line scalar weighted mean — the unblocked reference every
/// profile's kernel path must match bitwise.
fn scalar_reference(params: &[&[f32]], weights: &[f64], order: ReductionOrder) -> Vec<f32> {
    let wsum: f64 = weights.iter().sum();
    let w: Vec<f32> = weights.iter().map(|&x| (x / wsum) as f32).collect();
    let dim = params[0].len();
    let mut out = vec![0f32; dim];
    match order {
        ReductionOrder::Sequential => {
            for (p, &wi) in params.iter().zip(&w) {
                for j in 0..dim {
                    out[j] += wi * p[j];
                }
            }
        }
        ReductionOrder::Reversed => {
            for i in (0..params.len()).rev() {
                for j in 0..dim {
                    out[j] += w[i] * params[i][j];
                }
            }
        }
        ReductionOrder::Kahan => {
            let mut comp = vec![0f32; dim];
            for (p, &wi) in params.iter().zip(&w) {
                for j in 0..dim {
                    let y = wi * p[j] - comp[j];
                    let t = out[j] + y;
                    comp[j] = (t - out[j]) - y;
                    out[j] = t;
                }
            }
        }
        ReductionOrder::PairwiseTree => {
            for (j, o) in out.iter_mut().enumerate() {
                *o = scalar_pairwise_elem(params, &w, 0, params.len(), j);
            }
        }
    }
    out
}

#[test]
fn blocked_plan_matches_scalar_reference_bitwise() {
    // Small dims exercise the scalar-tail path (dim < LANES and dim just
    // past one block); the CHUNK+37 dim spans a chunk boundary with a
    // ragged tail in the second chunk.
    for &dim in &[13usize, 127, CHUNK + 37] {
        assert_ne!(dim % LANES, 0, "dim {dim} must not align to the block width");
        for &n in &[1usize, 3, 7, 10] {
            let (params, weights) = random_models(40_000 + (dim * 31 + n) as u64, n, dim);
            let refs: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
            for order in ReductionOrder::ALL {
                let golden = scalar_reference(&refs, &weights, order);
                for par in [1usize, 4, 8] {
                    let got =
                        weighted_mean_plan(&refs, &weights, AggPlan::new(order, par)).unwrap();
                    assert_eq!(
                        got, golden,
                        "{order:?} dim={dim} n={n} p{par} diverges from scalar reference"
                    );
                }
            }
        }
    }
}

#[test]
fn blocked_plan_matches_scalar_reference_with_parallel_chunking_engaged() {
    // 32 chunks + 5 ragged elements: enough chunks that parallelism 8
    // genuinely spawns 8 workers (the executor requires >= 4 chunks per
    // thread), with both a mid-vector block tail and a final partial chunk.
    let dim = 32 * CHUNK + 5;
    assert_ne!(dim % LANES, 0);
    let (params, weights) = random_models(41_000, 7, dim);
    let refs: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
    for order in ReductionOrder::ALL {
        let golden = scalar_reference(&refs, &weights, order);
        for par in [1usize, 8] {
            let got = weighted_mean_plan(&refs, &weights, AggPlan::new(order, par)).unwrap();
            assert_eq!(got, golden, "{order:?} p{par} diverges at dim={dim}");
        }
    }
}

#[test]
fn streaming_mean_matches_scalar_reference_bitwise() {
    // The streaming fold (recycled leaf buffers included) must land on the
    // same bits as the straight-line scalar reduction for every profile,
    // at cohort sizes around power-of-two boundaries and a ragged dim.
    let dim = CHUNK + 37;
    for &n in &[1usize, 2, 5, 8, 9, 16, 17] {
        let (params, weights) = random_models(42_000 + n as u64, n, dim);
        let refs: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
        let total: f64 = weights.iter().sum();
        for order in ReductionOrder::ALL {
            let golden = scalar_reference(&refs, &weights, order);
            let mut stream = StreamingMean::new(dim, total, order).unwrap();
            for (p, &w) in refs.iter().zip(&weights) {
                stream.push(p, w).unwrap();
            }
            assert_eq!(
                stream.finish().unwrap(),
                golden,
                "{order:?} streaming diverges at n={n}"
            );
        }
    }
}
