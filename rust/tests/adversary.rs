//! Adversarial-scenario contracts (the robustness PR's acceptance criteria):
//!
//! * **zero-adversary identity**: inactive `adversary:` / `faults:` /
//!   `aggregation:` sections produce bitwise-identical runs — model-hash
//!   series, traffic bytes and canonical cache keys — to a config without
//!   the sections at all (no RNG stream is touched, no cache key changes);
//! * **defense frontier**: under 30% scaled poisoning, krum and
//!   trimmed-mean strictly outperform plain weighted-mean aggregation;
//! * **worker invariance**: robust aggregation picks the same model at any
//!   worker count;
//! * **replayability**: churn and explicit fault schedules materialize and
//!   run deterministically end to end, and trace files round-trip through
//!   the config layer.

use std::sync::Arc;

use flsim::adversary::materialize_faults;
use flsim::campaign::CampaignSpec;
use flsim::config::adversary::{AttackKind, RobustAggConfig};
use flsim::config::job::JobConfig;
use flsim::metrics::report::RunReport;
use flsim::orchestrator::{Orchestrator, RunOptions};
use flsim::runtime::pjrt::Runtime;

fn rt() -> Arc<Runtime> {
    Runtime::shared("artifacts").unwrap()
}

fn tiny(strategy: &str) -> JobConfig {
    let mut j = JobConfig::default_cnn(strategy);
    j.name = "adv_tiny".into();
    j.rounds = 2;
    j.dataset.n = 600;
    j.n_clients = 4;
    j
}

/// A 10-client job under 30% scale-attack poisoning (λ = 10).
fn poisoned() -> JobConfig {
    let mut j = JobConfig::default_cnn("fedavg");
    j.name = "adv_poisoned".into();
    j.rounds = 3;
    j.dataset.n = 600;
    j.n_clients = 10;
    j.seed = 42;
    j.adversary.attack = AttackKind::Scale;
    j.adversary.attack_fraction = 0.3;
    j.adversary.scale = 10.0;
    j
}

fn hashes(r: &RunReport) -> Vec<String> {
    r.rounds.iter().map(|m| m.model_hash.clone()).collect()
}

fn net_bytes(r: &RunReport) -> Vec<u64> {
    r.rounds.iter().map(|m| m.net_bytes).collect()
}

/// The tentpole identity contract: configs whose adversary surface is
/// *present but inactive* must be indistinguishable — in results and in
/// cache keys — from configs predating the adversary layer entirely.
/// dpfl is included because its aggregation consumes RNG, so any stray
/// stream derivation would shift its noise and change the hashes.
#[test]
fn zero_adversary_runs_are_bitwise_identical() {
    for strategy in ["fedavg", "dpfl"] {
        let base = tiny(strategy);
        let orch = Orchestrator::new(rt());
        let want = orch.run(&base, RunOptions::default()).unwrap();

        let mut with_sections = tiny(strategy);
        with_sections.adversary.attack = AttackKind::Scale;
        with_sections.adversary.attack_fraction = 0.0; // inactive
        with_sections.adversary.scale = 10.0;
        with_sections.faults.churn = Some(flsim::config::adversary::ChurnConfig {
            availability: 1.0, // inactive
            from_round: 1,
        });
        with_sections.robust_agg = RobustAggConfig::parse_axis("none").unwrap();

        assert_eq!(
            base.canonical_json().to_string(),
            with_sections.canonical_json().to_string(),
            "{strategy}: inactive sections must not perturb the cache key"
        );
        let got = orch.run(&with_sections, RunOptions::default()).unwrap();
        assert_eq!(hashes(&want), hashes(&got), "{strategy}: model hashes diverged");
        assert_eq!(net_bytes(&want), net_bytes(&got), "{strategy}: traffic diverged");
    }
}

/// The robustness frontier, end to end: 3 of 10 clients submit λ=10
/// gradient-ascent updates. Plain weighted-mean aggregation is destroyed;
/// krum and trimmed-mean (auto f = |adversaries ∩ round| = 3) must both
/// strictly beat it. Deterministic engine ⇒ strict inequalities are stable.
#[test]
fn robust_aggregators_beat_weighted_mean_under_poisoning() {
    let orch = Orchestrator::new(rt());
    let undefended = orch.run(&poisoned(), RunOptions::default()).unwrap();

    let mut krum = poisoned();
    krum.robust_agg = RobustAggConfig::parse_axis("krum").unwrap();
    let krum = orch.run(&krum, RunOptions::default()).unwrap();

    let mut trimmed = poisoned();
    trimmed.robust_agg = RobustAggConfig::parse_axis("trimmed_mean").unwrap();
    let trimmed = orch.run(&trimmed, RunOptions::default()).unwrap();

    assert!(
        krum.final_accuracy() > undefended.final_accuracy(),
        "krum {} must beat weighted_mean {} under 30% scaled poisoning",
        krum.final_accuracy(),
        undefended.final_accuracy()
    );
    assert!(
        trimmed.final_accuracy() > undefended.final_accuracy(),
        "trimmed_mean {} must beat weighted_mean {} under 30% scaled poisoning",
        trimmed.final_accuracy(),
        undefended.final_accuracy()
    );
}

/// Robust aggregation must be a pure function of the client updates: with
/// 1 or 3 workers every worker computes the identical krum winner (no RNG
/// is consumed), so the consensus model — and the whole hash series — is
/// invariant to the worker count.
#[test]
fn robust_aggregation_is_worker_count_invariant() {
    let orch = Orchestrator::new(rt());
    let mut one = poisoned();
    one.robust_agg = RobustAggConfig::parse_axis("krum").unwrap();
    let mut three = one.clone();
    one.n_workers = 1;
    three.n_workers = 3;
    let a = orch.run(&one, RunOptions::default()).unwrap();
    let b = orch.run(&three, RunOptions::default()).unwrap();
    assert_eq!(hashes(&a), hashes(&b), "krum winner depends on worker count");
}

/// A label-flip data attack changes training (the poisoned shards differ)
/// — sanity that the scaffold-time mutation point is actually live.
#[test]
fn label_flip_changes_training() {
    let orch = Orchestrator::new(rt());
    let clean = orch.run(&tiny("fedavg"), RunOptions::default()).unwrap();
    let mut flipped = tiny("fedavg");
    flipped.adversary.attack = AttackKind::LabelFlip;
    flipped.adversary.attack_fraction = 0.5;
    let poisoned = orch.run(&flipped, RunOptions::default()).unwrap();
    assert_ne!(
        hashes(&clean),
        hashes(&poisoned),
        "label flipping on half the fleet must change the trained model"
    );
}

/// Stochastic churn materializes the same FaultPlan every time and the run
/// completes through the barrier-timeout machinery.
#[test]
fn churn_replays_deterministically_end_to_end() {
    let mut job = JobConfig::default_cnn("fedavg");
    job.name = "adv_churn".into();
    job.rounds = 3;
    job.dataset.n = 600;
    job.n_clients = 10;
    job.faults.churn = Some(flsim::config::adversary::ChurnConfig {
        availability: 0.9,
        from_round: 2,
    });
    let names: Vec<String> = (0..10).map(|i| format!("client_{i}")).collect();
    assert_eq!(
        format!("{:?}", materialize_faults(&job, &names)),
        format!("{:?}", materialize_faults(&job, &names)),
        "churn plan must be a pure function of the job"
    );
    let orch = Orchestrator::new(rt());
    let a = orch.run(&job, RunOptions::default()).unwrap();
    let b = orch.run(&job, RunOptions::default()).unwrap();
    assert_eq!(a.rounds.len(), 3);
    assert_eq!(hashes(&a), hashes(&b), "churn run must replay bit-for-bit");
}

/// Explicit `faults:` schedules ride the same barrier machinery as the
/// programmatic FaultPlan: a scheduled drop completes the run without the
/// dropped client's upload.
#[test]
fn declarative_drop_schedule_completes() {
    let mut job = tiny("fedavg");
    job.faults.drops.push(("client_1".into(), 2));
    let report = Orchestrator::new(rt()).run(&job, RunOptions::default()).unwrap();
    assert_eq!(report.rounds.len(), 2);
    // And it is a *different* trajectory from the clean run (client_1's
    // round-2 update is missing from the aggregate).
    let clean = Orchestrator::new(rt()).run(&tiny("fedavg"), RunOptions::default()).unwrap();
    assert_eq!(hashes(&report)[0], hashes(&clean)[0]);
    assert_ne!(hashes(&report)[1], hashes(&clean)[1]);
}

/// Trace files round-trip through the config layer: `faults: trace:` folds
/// the file's drop/crash lines into the parsed schedule.
#[test]
fn fault_trace_file_round_trips() {
    let path = std::env::temp_dir().join(format!("flsim_trace_{}.txt", std::process::id()));
    std::fs::write(
        &path,
        "# replayable fault trace\ndrop client_1 2\ncrash client_2 3\n\n",
    )
    .unwrap();
    let src = format!(
        "job:\n  name: traced\n  rounds: 4\nfaults:\n  trace: {}\ntopology:\n  kind: client_server\n  clients: 4\n  workers: 1\n",
        path.display()
    );
    let job = JobConfig::from_yaml_str(&src).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(job.faults.drops, vec![("client_1".to_string(), 2)]);
    assert_eq!(job.faults.crashes, vec![("client_2".to_string(), 3)]);
    job.validate().unwrap();
}

/// The shipped attack × defense sweep expands to the 6-cell grid the CI
/// smoke job greps for, with the adversary axes landing in each cell's job.
#[test]
fn adversary_sweep_spec_expands() {
    let spec = CampaignSpec::from_yaml_file("configs/adversary_sweep.yaml").unwrap();
    assert_eq!(spec.name, "adversary_sweep");
    let cells = flsim::campaign::expand(&spec).unwrap();
    assert_eq!(cells.len(), 6);
    let krum_poisoned = cells
        .iter()
        .find(|c| c.job.adversary.attack_fraction > 0.0 && c.job.robust_agg.kind.name() == "krum")
        .expect("poisoned krum cell in the grid");
    assert_eq!(krum_poisoned.job.adversary.attack, AttackKind::Scale);
    assert_eq!(krum_poisoned.job.adversary.scale, 10.0);
    // Poisoned and clean cells must hash differently (distinct cache keys).
    let clean_krum = cells
        .iter()
        .find(|c| c.job.adversary.attack_fraction == 0.0 && c.job.robust_agg.kind.name() == "krum")
        .unwrap();
    assert_ne!(krum_poisoned.key, clean_krum.key);
}
