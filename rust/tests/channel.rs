//! Composable channel-layer contracts (the channel PR's acceptance
//! criteria):
//!
//! * **dpfl supersession**: `fedavg` + `channel.dp` at the legacy strategy's
//!   defaults reproduces a `dpfl` run bit for bit — same model-hash series,
//!   same traffic — while only the channel path reports the DP accountant;
//! * **inactive identity**: a `channel:` section that is present but
//!   inactive is indistinguishable — in cache keys and in runs — from no
//!   section at all;
//! * **compression frontier**: tightening the codec (none → top_k →
//!   quantize) strictly shrinks both `net_bytes` and the simulated round
//!   clock, because uploads are metered at compressed wire size;
//! * **secure aggregation**: share traffic is metered, dropped-client
//!   recovery is priced, and an unmet unmasking threshold aborts the run;
//! * **streaming goldens**: fedprox / fedavgm / channel.dp on a virtual
//!   population (StreamingMean fold) match the eager path bitwise.

use std::sync::Arc;

use flsim::campaign::CampaignSpec;
use flsim::config::channel::{DpConfig, SecureAggConfig};
use flsim::config::job::{JobConfig, PopulationMode};
use flsim::metrics::report::RunReport;
use flsim::orchestrator::{Orchestrator, RunOptions};
use flsim::runtime::pjrt::Runtime;
use flsim::strategy::StrategyKind;
use flsim::util::yaml::Yaml;

fn rt() -> Arc<Runtime> {
    Runtime::shared("artifacts").unwrap()
}

fn tiny(strategy: &str) -> JobConfig {
    let mut j = JobConfig::default_cnn(strategy);
    j.name = "chan_tiny".into();
    j.rounds = 2;
    j.dataset.n = 600;
    j.n_clients = 4;
    j
}

fn hashes(r: &RunReport) -> Vec<String> {
    r.rounds.iter().map(|m| m.model_hash.clone()).collect()
}

fn net_bytes(r: &RunReport) -> Vec<u64> {
    r.rounds.iter().map(|m| m.net_bytes).collect()
}

fn sim_secs(r: &RunReport) -> f64 {
    r.rounds.iter().map(|m| m.sim_round_secs).sum()
}

/// The tentpole pin: the legacy `dpfl` strategy is now *defined* as
/// `fedavg` + `channel.dp` at its default clip/σ. Both paths clip each
/// update against the same global, run the same weighted mean, and draw
/// noise from the same `"dp_noise"` stream — so the hash series must agree
/// bit for bit. Only the channel path carries the privacy accountant.
#[test]
fn fedavg_plus_channel_dp_reproduces_dpfl_bitwise() {
    let orch = Orchestrator::new(rt());
    let legacy = orch.run(&tiny("dpfl"), RunOptions::default()).unwrap();

    let mut composed = tiny("fedavg");
    // dpfl's parse defaults (strategy/mod.rs): clip 10.0, sigma 0.005.
    composed.channel.dp = Some(DpConfig {
        clip: 10.0,
        sigma: 0.005,
        delta: 1e-5,
    });
    let composed = orch.run(&composed, RunOptions::default()).unwrap();

    assert_eq!(
        hashes(&legacy),
        hashes(&composed),
        "fedavg + channel.dp must reproduce dpfl bit for bit"
    );
    assert_eq!(
        net_bytes(&legacy),
        net_bytes(&composed),
        "the composed channel must not change wire traffic"
    );

    // The accountant lives on the channel path only: the legacy strategy
    // reports zero spend, the composed run reports ε growing linearly.
    assert_eq!(legacy.rounds.last().unwrap().dp_epsilon, 0.0);
    let e1 = composed.rounds[0].dp_epsilon;
    let e2 = composed.rounds[1].dp_epsilon;
    assert!(e1 > 0.0, "channel.dp run must report a per-round ε");
    assert!(
        (e2 - 2.0 * e1).abs() < 1e-9,
        "linear composition: ε(2) = 2·ε(1), got {e1} then {e2}"
    );
    assert_eq!(composed.rounds[1].dp_delta, 2e-5);
}

/// Identity contract through a real run: junk parameters behind an
/// inactive codec (`kind: none`) must not perturb the cache key, the RNG
/// streams, or a single byte of the run.
#[test]
fn inactive_channel_section_is_bitwise_invisible() {
    let base = tiny("fedavg");
    let orch = Orchestrator::new(rt());
    let want = orch.run(&base, RunOptions::default()).unwrap();

    let mut with_section = tiny("fedavg");
    with_section.channel.compress.k = 9_999; // ignored: kind is none
    with_section.channel.compress.bits = 8;

    assert_eq!(
        base.canonical_json().to_string(),
        with_section.canonical_json().to_string(),
        "inactive channel must not perturb the cache key"
    );
    let got = orch.run(&with_section, RunOptions::default()).unwrap();
    assert_eq!(hashes(&want), hashes(&got), "model hashes diverged");
    assert_eq!(net_bytes(&want), net_bytes(&got), "traffic diverged");
}

/// The compression frontier, end to end: per-upload wire size is
/// 64 + 4·d dense, 64 + 4 + 8k for top_k, 64 + 12 + ⌈d·bits/8⌉ quantized
/// (d = 74 002 for the cnn backend), so both total traffic and the
/// simulated clock must strictly shrink as the codec tightens — and the
/// lossy codecs must actually bend the model trajectory.
#[test]
fn tighter_compression_strictly_shrinks_wire_traffic() {
    let orch = Orchestrator::new(rt());
    let dense = orch.run(&tiny("fedavg"), RunOptions::default()).unwrap();

    let mut sparse = tiny("fedavg");
    sparse.channel.compress =
        flsim::config::channel::ChannelConfig::parse_compress_axis("top_k:8000").unwrap();
    let sparse = orch.run(&sparse, RunOptions::default()).unwrap();

    let mut quant = tiny("fedavg");
    quant.channel.compress =
        flsim::config::channel::ChannelConfig::parse_compress_axis("quantize:4").unwrap();
    let quant = orch.run(&quant, RunOptions::default()).unwrap();

    for r in 0..2 {
        assert!(
            net_bytes(&dense)[r] > net_bytes(&sparse)[r]
                && net_bytes(&sparse)[r] > net_bytes(&quant)[r],
            "round {r}: net_bytes must strictly shrink as the codec tightens \
             ({} > {} > {} expected)",
            net_bytes(&dense)[r],
            net_bytes(&sparse)[r],
            net_bytes(&quant)[r]
        );
    }
    assert!(
        sim_secs(&dense) > sim_secs(&sparse) && sim_secs(&sparse) > sim_secs(&quant),
        "sim_round_secs must reflect compressed wire volume ({} > {} > {} expected)",
        sim_secs(&dense),
        sim_secs(&sparse),
        sim_secs(&quant)
    );
    // Lossy codecs are live: the trajectory diverges from the dense run,
    // yet each compressed run replays deterministically.
    assert_ne!(hashes(&dense), hashes(&sparse), "top_k must be live");
    assert_ne!(hashes(&dense), hashes(&quant), "quantize must be live");
    let mut quant2 = tiny("fedavg");
    quant2.channel.compress =
        flsim::config::channel::ChannelConfig::parse_compress_axis("quantize:4").unwrap();
    let quant2 = orch.run(&quant2, RunOptions::default()).unwrap();
    assert_eq!(
        hashes(&quant),
        hashes(&quant2),
        "stochastic quantization must replay bit for bit under a fixed seed"
    );
}

/// Secure aggregation's cost model: every landed upload publishes a
/// 32·n-byte masking-share vector, so the metered traffic strictly exceeds
/// the plain run's; a scheduled drop still completes (the survivors replay
/// the dropped client's shares) as long as the threshold is met.
#[test]
fn secure_agg_shares_are_metered() {
    let orch = Orchestrator::new(rt());
    let plain = orch.run(&tiny("fedavg"), RunOptions::default()).unwrap();

    let mut sa = tiny("fedavg");
    sa.channel.secure_agg = Some(SecureAggConfig { threshold: 2 });
    let sa_run = orch.run(&sa, RunOptions::default()).unwrap();
    assert_eq!(
        hashes(&plain),
        hashes(&sa_run),
        "secure agg is a cost model — the aggregate itself is unchanged"
    );
    for r in 0..2 {
        assert!(
            net_bytes(&sa_run)[r] > net_bytes(&plain)[r],
            "round {r}: share traffic must be metered"
        );
    }

    // A drop above threshold: the run completes and round 2 pays the
    // recovery transfers on the simulated clock.
    let mut dropped = tiny("fedavg");
    dropped.channel.secure_agg = Some(SecureAggConfig { threshold: 2 });
    dropped.faults.drops.push(("client_1".into(), 2));
    let dropped_run = orch.run(&dropped, RunOptions::default()).unwrap();
    assert_eq!(dropped_run.rounds.len(), 2);

    let mut plain_dropped = tiny("fedavg");
    plain_dropped.faults.drops.push(("client_1".into(), 2));
    let plain_dropped = orch.run(&plain_dropped, RunOptions::default()).unwrap();
    assert!(
        dropped_run.rounds[1].sim_round_secs > plain_dropped.rounds[1].sim_round_secs,
        "dropped-client recovery must cost simulated time"
    );
}

/// Below the unmasking threshold the sum is unrecoverable — the run must
/// abort with an actionable error, not silently aggregate fewer clients.
#[test]
fn secure_agg_threshold_shortfall_aborts() {
    let mut job = tiny("fedavg");
    job.channel.secure_agg = Some(SecureAggConfig { threshold: 4 });
    job.faults.drops.push(("client_1".into(), 2));
    let err = Orchestrator::new(rt()).run(&job, RunOptions::default()).unwrap_err().to_string();
    assert!(
        err.contains("secure aggregation"),
        "want a threshold-shortfall error, got: {err}"
    );
}

/// Compare every deterministic per-round metric bit for bit (the
/// virtual-population golden idiom; host-dependent columns excluded).
fn assert_reports_identical(eager: &RunReport, virt: &RunReport, tag: &str) {
    assert_eq!(eager.rounds.len(), virt.rounds.len(), "{tag}: round count");
    for (e, v) in eager.rounds.iter().zip(&virt.rounds) {
        let r = e.round;
        assert_eq!(e.model_hash, v.model_hash, "{tag}: model hash, round {r}");
        assert_eq!(e.net_bytes, v.net_bytes, "{tag}: net bytes, round {r}");
        assert_eq!(
            e.dp_epsilon.to_bits(),
            v.dp_epsilon.to_bits(),
            "{tag}: dp_epsilon, round {r}"
        );
    }
}

/// Streaming goldens: strategies newly routed through the O(model)
/// StreamingMean fold on virtual fleets — fedprox, fedavgm, and the
/// channel.dp clip-fold — must match their eager collect-then-reduce twins
/// bit for bit.
#[test]
fn virtual_streaming_matches_eager_for_mean_shaped_strategies() {
    for strategy in ["fedprox", "fedavgm"] {
        let mut job = JobConfig::scale_logreg(10);
        job.name = format!("chan_virt_{strategy}");
        job.strategy = StrategyKind::parse(strategy, &Yaml::Null).unwrap();
        job.dataset.n = 600;
        job.rounds = 3;
        job.client_fraction = 0.5;

        job.population = PopulationMode::Eager;
        let eager = Orchestrator::new(rt()).run(&job, RunOptions::default()).unwrap();
        job.population = PopulationMode::Virtual;
        let virt = Orchestrator::new(rt()).run(&job, RunOptions::default()).unwrap();
        assert_reports_identical(&eager, &virt, strategy);
    }
}

#[test]
fn virtual_streaming_matches_eager_under_channel_dp() {
    let mut job = JobConfig::scale_logreg(10);
    job.name = "chan_virt_dp".into();
    job.dataset.n = 600;
    job.rounds = 3;
    job.client_fraction = 0.5;
    job.channel.dp = Some(DpConfig {
        clip: 5.0,
        sigma: 0.01,
        delta: 1e-5,
    });

    job.population = PopulationMode::Eager;
    let eager = Orchestrator::new(rt()).run(&job, RunOptions::default()).unwrap();
    job.population = PopulationMode::Virtual;
    let virt = Orchestrator::new(rt()).run(&job, RunOptions::default()).unwrap();
    assert_reports_identical(&eager, &virt, "channel.dp");
    assert!(virt.rounds.last().unwrap().dp_epsilon > 0.0);
}

/// The shipped compression × DP sweep expands to the 6-cell grid the CI
/// smoke job greps for, with the channel axes landing in each cell's job.
#[test]
fn channel_sweep_spec_expands() {
    let spec = CampaignSpec::from_yaml_file("configs/channel_sweep.yaml").unwrap();
    assert_eq!(spec.name, "channel_sweep");
    let cells = flsim::campaign::expand(&spec).unwrap();
    assert_eq!(cells.len(), 6);

    let quant_dp = cells
        .iter()
        .find(|c| c.job.channel.compress.label() == "quantize:4" && c.job.channel.dp.is_some())
        .expect("quantize:4 × dp_sigma 0.01 cell in the grid");
    let dp = quant_dp.job.channel.dp.unwrap();
    assert_eq!(dp.sigma, 0.01);
    assert_eq!(dp.clip, flsim::config::channel::DpConfig::DEFAULT_CLIP);

    // dp_sigma 0.0 leaves channel.dp absent entirely (identity contract).
    let clean_dense = cells
        .iter()
        .find(|c| !c.job.channel.compress.is_active() && c.job.channel.dp.is_none())
        .expect("clean baseline cell in the grid");
    assert_ne!(quant_dp.key, clean_dense.key, "cells must hash distinctly");
    let keys: std::collections::BTreeSet<&String> = cells.iter().map(|c| &c.key).collect();
    assert_eq!(keys.len(), 6, "all six cells must have distinct cache keys");
}

/// NaN-safety regression for the top_k codec, through a real adversarial
/// job: a λ = 1e39 scale attack overflows f32 (the λ cast alone is ±inf),
/// so poisoned uploads — and therefore the aggregated global and every
/// subsequent client delta — carry ±inf and NaN (inf · 0, inf − inf). The
/// old magnitude comparator (`partial_cmp(..).unwrap()`) panicked on the
/// first NaN; the `total_cmp` selection must instead rank NaNs strictly
/// last and let the run complete — deterministically, since poisoned bit
/// patterns replay exactly.
#[test]
fn topk_survives_non_finite_poisoned_uploads() {
    let mut job = tiny("fedavg");
    job.name = "chan_nan_topk".into();
    job.adversary.attack = flsim::config::adversary::AttackKind::Scale;
    job.adversary.attack_fraction = 0.5;
    job.adversary.scale = 1e39; // > f32::MAX: non-finite from round 1 on
    job.channel.compress =
        flsim::config::channel::ChannelConfig::parse_compress_axis("top_k:500").unwrap();

    let orch = Orchestrator::new(rt());
    let a = orch.run(&job, RunOptions::default()).unwrap();
    assert_eq!(a.rounds.len(), 2, "poisoned top_k run must complete");
    let b = orch.run(&job, RunOptions::default()).unwrap();
    assert_eq!(
        hashes(&a),
        hashes(&b),
        "non-finite top_k selection must replay bit for bit"
    );
}
