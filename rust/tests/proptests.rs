//! Property-based tests on coordinator invariants (hand-rolled generator
//! harness — proptest is not vendored in this offline image). Each property
//! runs over a couple hundred seeded random cases; failures print the
//! offending seed for replay.

use flsim::aggregate::mean::{weighted_mean, ReductionOrder};
use flsim::aggregate::robust::{coordinate_median, trimmed_mean};
use flsim::campaign::{self, CampaignSpec};
use flsim::config::job::JobConfig;
use flsim::consensus::{by_name, Proposal};
use flsim::data::dataset::Distribution;
use flsim::data::partition::Partition;
use flsim::data::synthetic;
use flsim::kvstore::store::{KvStore, Payload};
use flsim::topology::graph::{Overlay, TopologyKind};
use flsim::util::rng::Rng;
use flsim::util::yaml::Yaml;

/// Run `prop` over `cases` seeded cases.
fn forall(cases: u64, prop: impl Fn(&mut Rng) -> Result<(), String>) {
    for seed in 0..cases {
        let mut rng = Rng::seed_from(0xF00D + seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed at seed {seed}: {msg}");
        }
    }
}

#[test]
fn prop_partition_is_exact_cover() {
    forall(60, |rng| {
        let n = 50 + rng.below(400);
        let clients = 2 + rng.below(20);
        let dist = match rng.below(3) {
            0 => Distribution::Iid,
            1 => Distribution::Dirichlet {
                alpha: 0.1 + rng.next_f64() * 2.0,
            },
            _ => Distribution::Shards {
                shards_per_client: 1 + rng.below(3),
            },
        };
        let ds = synthetic::mnist_synth(n, rng.next_u64());
        let p = Partition::build(&ds, clients, &dist, rng);
        // Exact cover: every index assigned exactly once.
        let mut seen = vec![false; n];
        for a in &p.assignments {
            for &i in a {
                if seen[i] {
                    return Err(format!("index {i} assigned twice ({dist:?})"));
                }
                seen[i] = true;
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err(format!("not all of {n} examples assigned ({dist:?})"));
        }
        // No starving clients.
        if p.assignments.iter().any(Vec::is_empty) {
            return Err(format!("empty client under {dist:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_weighted_mean_within_hull_and_orders_agree() {
    forall(120, |rng| {
        let n = 1 + rng.below(12);
        let dim = 1 + rng.below(200);
        let models: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..dim).map(|_| rng.normal_f32() * 5.0).collect())
            .collect();
        let refs: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
        let weights: Vec<f64> = (0..n).map(|_| 0.1 + rng.next_f64() * 9.9).collect();
        let base = weighted_mean(&refs, &weights, ReductionOrder::Sequential)
            .map_err(|e| e.to_string())?;
        // Convex-hull bound per coordinate.
        for j in 0..dim {
            let lo = refs.iter().map(|p| p[j]).fold(f32::INFINITY, f32::min);
            let hi = refs.iter().map(|p| p[j]).fold(f32::NEG_INFINITY, f32::max);
            if base[j] < lo - 1e-3 || base[j] > hi + 1e-3 {
                return Err(format!("coordinate {j} out of hull"));
            }
        }
        // All reduction orders agree within fp tolerance.
        for order in ReductionOrder::ALL {
            let other = weighted_mean(&refs, &weights, order).map_err(|e| e.to_string())?;
            for j in 0..dim {
                if (other[j] - base[j]).abs() > 1e-3 {
                    return Err(format!("{order:?} diverges at {j}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_robust_aggregators_bounded_by_extremes() {
    forall(80, |rng| {
        let n = 3 + rng.below(10);
        let dim = 1 + rng.below(50);
        let models: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..dim).map(|_| rng.normal_f32() * 3.0).collect())
            .collect();
        let refs: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
        let med = coordinate_median(&refs).map_err(|e| e.to_string())?;
        let trim = (n - 1) / 2;
        let tm = trimmed_mean(&refs, trim.min((n - 1) / 2)).map_err(|e| e.to_string())?;
        for j in 0..dim {
            let lo = refs.iter().map(|p| p[j]).fold(f32::INFINITY, f32::min);
            let hi = refs.iter().map(|p| p[j]).fold(f32::NEG_INFINITY, f32::max);
            if med[j] < lo || med[j] > hi {
                return Err("median out of range".into());
            }
            if tm[j] < lo - 1e-4 || tm[j] > hi + 1e-4 {
                return Err("trimmed mean out of range".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_consensus_honest_majority_always_wins() {
    let consensus = by_name("majority_hash").unwrap();
    forall(150, |rng| {
        let honest = 2 + rng.below(4); // 2..5 honest
        let malicious = 1 + rng.below(honest - 1); // strictly fewer malicious
        let dim = 1 + rng.below(64);
        let honest_params: Vec<f32> = (0..dim).map(|_| rng.normal_f32()).collect();
        let mut proposals = Vec::new();
        for m in 0..malicious {
            // Each attacker submits its own (distinct) poison.
            let poison: Vec<f32> = honest_params
                .iter()
                .map(|&v| -v + m as f32 + rng.normal_f32())
                .collect();
            proposals.push(Proposal::new(format!("mal_{m}"), poison));
        }
        for h in 0..honest {
            proposals.push(Proposal::new(format!("h_{h}"), honest_params.clone()));
        }
        let d = consensus.decide(&proposals, rng).map_err(|e| e.to_string())?;
        if proposals[d.winner].params != honest_params {
            return Err(format!(
                "poison won with {malicious} malicious vs {honest} honest"
            ));
        }
        if !d.decisive {
            return Err("honest majority should be decisive".into());
        }
        Ok(())
    });
}

#[test]
fn prop_overlay_invariants_all_topologies() {
    forall(60, |rng| {
        let n = 2 + rng.below(30);
        let w = 1 + rng.below(4);
        for kind in [
            TopologyKind::ClientServer,
            TopologyKind::Hierarchical,
            TopologyKind::FullyConnected,
            TopologyKind::Ring,
        ] {
            let o = Overlay::build(kind, n, w);
            o.validate().map_err(|e| format!("{kind:?}: {e}"))?;
            if o.clients().is_empty() {
                return Err(format!("{kind:?}: no clients"));
            }
            // Edges reference known nodes both ways; neighbors symmetric for
            // undirected-by-construction topologies.
            for (a, b) in &o.edges {
                if a == b {
                    return Err(format!("{kind:?}: self-loop"));
                }
                if !o.roles.contains_key(a) || !o.roles.contains_key(b) {
                    return Err(format!("{kind:?}: dangling edge"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_kvstore_conservation_of_bytes() {
    forall(80, |rng| {
        let mut kv = KvStore::new();
        let nodes = 2 + rng.below(8);
        let mut expected_total = 0u64;
        for round in 0..1 + rng.below(5) as u64 {
            for i in 0..nodes {
                let len = rng.below(500);
                let payload = Payload::Params((0..len).map(|_| rng.normal_f32()).collect());
                expected_total += payload.wire_bytes();
                kv.publish("t", &format!("n{i}"), round, payload);
            }
            // One reader drains the round.
            let msgs = kv.fetch_round("t", round, "reader");
            if msgs.len() != nodes {
                return Err("lost messages".into());
            }
            for m in &msgs {
                expected_total += m.payload.wire_bytes();
            }
        }
        if kv.total_bytes() != expected_total {
            return Err(format!(
                "byte conservation broken: {} != {expected_total}",
                kv.total_bytes()
            ));
        }
        // Egress of writers == ingress of reader.
        let out: u64 = (0..nodes)
            .map(|i| kv.traffic(&format!("n{i}")).bytes_out)
            .sum();
        let inn = kv.traffic("reader").bytes_in;
        if out != inn {
            return Err(format!("egress {out} != ingress {inn}"));
        }
        Ok(())
    });
}

#[test]
fn prop_yaml_scalar_roundtrip() {
    forall(100, |rng| {
        // Random flat configs stay parseable and value-stable.
        let n_keys = 1 + rng.below(10);
        let mut src = String::new();
        let mut expect = Vec::new();
        for k in 0..n_keys {
            match rng.below(3) {
                0 => {
                    let v = rng.below(100000) as i64;
                    src.push_str(&format!("k{k}: {v}\n"));
                    expect.push((format!("k{k}"), Yaml::Int(v)));
                }
                1 => {
                    let v = (rng.next_f64() * 100.0 * 8.0).round() / 8.0; // exact in binary
                    src.push_str(&format!("k{k}: {v:?}\n"));
                    expect.push((format!("k{k}"), Yaml::Float(v)));
                }
                _ => {
                    src.push_str(&format!("k{k}: value_{k}\n"));
                    expect.push((format!("k{k}"), Yaml::Str(format!("value_{k}"))));
                }
            }
        }
        let y = Yaml::parse(&src).map_err(|e| e.to_string())?;
        for (k, v) in expect {
            let got = y.get(&k).ok_or(format!("missing {k}"))?;
            if got != &v {
                return Err(format!("{k}: {got:?} != {v:?}"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Campaign grid-expansion invariants (random axis maps).
// ---------------------------------------------------------------------------

/// A random campaign spec over the supported sweep axes. Returns the spec
/// plus the per-axis value counts (for the cell-count property). Axes are
/// *inserted* in a random order so the expansion-order properties exercise
/// name reordering.
fn random_grid_spec(rng: &mut Rng) -> (CampaignSpec, Vec<usize>) {
    let mut base = JobConfig::default_cnn("fedavg");
    base.name = "prop_base".into();
    base.rounds = 2;
    base.dataset.n = 600;
    base.n_clients = 4;

    // Pools of distinct values per eligible axis.
    let pools: Vec<(&str, Vec<Yaml>)> = vec![
        (
            "strategy",
            vec!["fedavg", "fedprox", "scaffold", "fedstellar"]
                .into_iter()
                .map(Yaml::from)
                .collect(),
        ),
        (
            "topology",
            vec!["client_server", "ring", "fully_connected"]
                .into_iter()
                .map(Yaml::from)
                .collect(),
        ),
        ("seed", (1..=4).map(Yaml::Int).collect()),
        ("rounds", vec![Yaml::Int(1), Yaml::Int(2), Yaml::Int(3)]),
        ("local_epochs", vec![Yaml::Int(1), Yaml::Int(2)]),
        (
            "learning_rate",
            vec![Yaml::Float(0.01), Yaml::Float(0.02), Yaml::Float(0.05)],
        ),
        ("heterogeneity", vec![Yaml::Float(0.0), Yaml::Float(0.5)]),
    ];

    // Pick 1..=4 random axes in random insertion order, each with a random
    // non-empty prefix of its (distinct) value pool.
    let n_axes = 1 + rng.below(4);
    let mut order: Vec<usize> = (0..pools.len()).collect();
    // Deterministic shuffle.
    for i in (1..order.len()).rev() {
        let j = rng.below(i + 1);
        order.swap(i, j);
    }
    let mut spec = CampaignSpec::builder("prop_grid", base);
    let mut lens = Vec::new();
    for &pi in order.iter().take(n_axes) {
        let (axis, pool) = &pools[pi];
        let take = 1 + rng.below(pool.len());
        spec = spec.axis(axis, pool[..take].to_vec());
        lens.push(take);
    }
    (spec.build(), lens)
}

/// How many grid points of `spec` are strategy/topology-incompatible (the
/// expansion skips them when the topology axis is swept). Computed here by
/// brute force over the cartesian product, independent of the expansion's
/// own enumeration.
fn incompatible_points(spec: &CampaignSpec) -> Result<usize, String> {
    if !spec.axes.contains_key("topology") {
        return Ok(0);
    }
    let axes: Vec<(&String, &Vec<Yaml>)> = spec.axes.iter().collect();
    let total: usize = axes.iter().map(|(_, v)| v.len()).product();
    let mut bad = 0;
    for mut idx in 0..total {
        let mut job = spec.base.clone();
        for (name, vals) in axes.iter().rev() {
            let pick = idx % vals.len();
            idx /= vals.len();
            campaign::spec::apply_axis(&mut job, name, &vals[pick])
                .map_err(|e| e.to_string())?;
        }
        if flsim::orchestrator::check_topology(&job).is_err() {
            bad += 1;
        }
    }
    Ok(bad)
}

#[test]
fn prop_grid_expansion_deterministic_under_axis_reordering() {
    forall(60, |rng| {
        let (spec, _) = random_grid_spec(rng);
        // Rebuild the same spec with axes inserted in reversed order: the
        // BTreeMap canonicalizes, so expansion must be identical.
        let mut reordered = CampaignSpec::builder("prop_grid", spec.base.clone());
        for (axis, vals) in spec.axes.iter().rev() {
            reordered = reordered.axis(axis, vals.clone());
        }
        let (a, b) = match (campaign::expand(&spec), campaign::expand(&reordered.build())) {
            // An all-incompatible grid errors — identically under
            // reordering.
            (Err(_), Err(_)) => return Ok(()),
            (Ok(a), Ok(b)) => (a, b),
            (a, b) => {
                return Err(format!(
                    "reordering changed expandability: {:?} vs {:?}",
                    a.map(|c| c.len()),
                    b.map(|c| c.len())
                ))
            }
        };
        if a.len() != b.len() {
            return Err(format!("reordering changed cell count: {} vs {}", a.len(), b.len()));
        }
        for (ca, cb) in a.iter().zip(&b) {
            if ca.name != cb.name || ca.key != cb.key {
                return Err(format!(
                    "reordering changed cell: {} / {} vs {} / {}",
                    ca.name, ca.key, cb.name, cb.key
                ));
            }
        }
        // And a straight re-expansion is idempotent.
        let c = campaign::expand(&spec).map_err(|e| e.to_string())?;
        if a.len() != c.len() || a.iter().zip(&c).any(|(x, y)| x.key != y.key) {
            return Err("re-expansion diverged".into());
        }
        Ok(())
    });
}

#[test]
fn prop_grid_dedup_never_drops_distinct_keys() {
    forall(60, |rng| {
        let (spec, _) = random_grid_spec(rng);
        let cells = match campaign::expand(&spec) {
            // All-incompatible grids error (covered by the count property).
            Err(_) => return Ok(()),
            Ok(c) => c,
        };
        // All surviving keys are pairwise distinct ...
        let keys: std::collections::BTreeSet<&String> = cells.iter().map(|c| &c.key).collect();
        if keys.len() != cells.len() {
            return Err("expansion emitted duplicate keys".into());
        }
        // ... and dedup only ever removes *identical* configs: repeating an
        // axis's value list verbatim doubles the raw product but must leave
        // the distinct cell set unchanged — no distinct key is dropped, no
        // duplicate survives.
        for (axis, vals) in &spec.axes {
            let mut rep = spec.clone();
            let mut twice = vals.clone();
            twice.extend(vals.iter().cloned());
            rep.axes.insert(axis.clone(), twice);
            let expanded = campaign::expand(&rep).map_err(|e| e.to_string())?;
            if expanded.len() != cells.len() {
                return Err(format!(
                    "repeating axis '{axis}' values changed the distinct cell count: \
                     {} vs {}",
                    expanded.len(),
                    cells.len()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_grid_cell_count_is_product_minus_skips() {
    forall(60, |rng| {
        let (spec, lens) = random_grid_spec(rng);
        let product: usize = lens.iter().product();
        let skipped = incompatible_points(&spec)?;
        let cells = campaign::expand(&spec);
        if product == skipped {
            // Every point incompatible: expansion must error, not succeed
            // empty.
            return match cells {
                Err(_) => Ok(()),
                Ok(c) => Err(format!("all-skipped grid expanded to {} cells", c.len())),
            };
        }
        let cells = cells.map_err(|e| e.to_string())?;
        // Distinct-config count: the cartesian product minus incompatible
        // points, minus key-level duplicates (possible when two different
        // axis picks resolve to one config — e.g. a decentralized strategy
        // forcing the topology). Compute expected distinct keys by brute
        // force.
        let axes: Vec<(&String, &Vec<Yaml>)> = spec.axes.iter().collect();
        let mut expect = std::collections::BTreeSet::new();
        let topology_swept = spec.axes.contains_key("topology");
        for mut idx in 0..product {
            let mut job = spec.base.clone();
            let mut picks = Vec::new();
            for (name, vals) in axes.iter().rev() {
                let pick = idx % vals.len();
                idx /= vals.len();
                picks.push((name.to_string(), vals[pick].clone()));
            }
            picks.reverse();
            for (name, val) in &picks {
                campaign::spec::apply_axis(&mut job, name, val)
                    .map_err(|e| e.to_string())?;
            }
            if topology_swept && flsim::orchestrator::check_topology(&job).is_err() {
                continue;
            }
            if flsim::orchestrator::check_topology(&job).is_err() {
                job.topology = flsim::topology::TopologyKind::FullyConnected;
            }
            job.name = picks
                .iter()
                .map(|(n, v)| campaign::spec::name_part(n, v))
                .collect::<Vec<_>>()
                .join("_");
            expect.insert(campaign::cell_key(&job));
        }
        if cells.len() != expect.len() {
            return Err(format!(
                "cell count {} != product {} - skipped {} (distinct {})",
                cells.len(),
                product,
                skipped,
                expect.len()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_rng_streams_never_collide() {
    forall(50, |rng| {
        let root = Rng::seed_from(rng.next_u64());
        let mut a = root.derive("purpose_a", 0);
        let mut b = root.derive("purpose_b", 0);
        let mut c = root.derive("purpose_a", 1);
        let (va, vb, vc) = (a.next_u64(), b.next_u64(), c.next_u64());
        if va == vb || va == vc || vb == vc {
            return Err("derived streams collided".into());
        }
        Ok(())
    });
}

#[test]
fn prop_inactive_adversary_sections_never_shift_cache_keys() {
    // The zero-adversary identity contract at the cache-key layer: over
    // random jobs, bolting on *inactive* adversary/faults/aggregation/
    // channel sections leaves the canonical key byte-identical, while
    // activating any one of them changes it.
    forall(80, |rng| {
        let mut base = JobConfig::default_cnn("fedavg");
        base.seed = rng.next_u64() % 1_000_000;
        base.rounds = 1 + rng.below(20) as u64;
        base.n_clients = 2 + rng.below(12);
        let key = base.canonical_json().to_string();

        let mut inactive = base.clone();
        inactive.adversary.attack_fraction = 0.0;
        inactive.adversary.scale = 1.0 + rng.next_f64() * 20.0;
        inactive.faults.churn = Some(flsim::config::adversary::ChurnConfig {
            availability: 1.0,
            from_round: 1 + rng.next_u64() % 5,
        });
        // kind: none with junk stage parameters is still the identity
        // channel — the parameters are contractually invisible.
        inactive.channel.compress.k = rng.below(10_000);
        inactive.channel.compress.bits = rng.below(16) as u8;
        if inactive.canonical_json().to_string() != key {
            return Err("inactive sections changed the canonical key".into());
        }

        let mut active = base.clone();
        match rng.below(5) {
            0 => active.adversary.attack_fraction = 0.1 + rng.next_f64() * 0.8,
            1 => active.faults.drops.push((format!("client_{}", rng.below(4)), 2)),
            2 => {
                active.robust_agg =
                    flsim::config::adversary::RobustAggConfig::parse_axis("krum").unwrap()
            }
            3 => {
                active.channel.compress =
                    flsim::config::channel::ChannelConfig::parse_compress_axis(&format!(
                        "top_k:{}",
                        1 + rng.below(10_000)
                    ))
                    .unwrap()
            }
            _ => {
                active.channel.dp = Some(flsim::config::channel::DpConfig {
                    clip: 10.0,
                    sigma: 0.001 + rng.next_f64(),
                    delta: 1e-5,
                })
            }
        }
        if active.canonical_json().to_string() == key {
            return Err("an active section failed to change the canonical key".into());
        }
        Ok(())
    });
}

#[test]
fn prop_adversary_selection_and_churn_are_pure() {
    // Attacker cohorts and churn plans must be pure functions of
    // (config, seed): same inputs, same outputs — and cohort size must
    // follow round(fraction · n).
    forall(80, |rng| {
        let n = 4 + rng.below(20);
        let names: Vec<String> = (0..n).map(|i| format!("client_{i}")).collect();
        let fraction = rng.next_f64();
        let adv = flsim::config::adversary::AdversaryConfig {
            attack: flsim::config::adversary::AttackKind::Scale,
            attack_fraction: fraction,
            scale: 10.0,
            nodes: vec![],
        };
        let root = Rng::seed_from(rng.next_u64());
        let a = flsim::adversary::select_adversaries(&adv, &root, &names)
            .map_err(|e| e.to_string())?;
        let b = flsim::adversary::select_adversaries(&adv, &root, &names)
            .map_err(|e| e.to_string())?;
        if a != b {
            return Err("adversary selection is not deterministic".into());
        }
        let want = ((fraction * n as f64).round() as usize).min(n);
        if a.len() != want {
            return Err(format!("cohort {} != round({fraction} * {n}) = {want}", a.len()));
        }

        let mut job = JobConfig::default_cnn("fedavg");
        job.seed = rng.next_u64();
        job.rounds = 2 + rng.next_u64() % 10;
        job.faults.churn = Some(flsim::config::adversary::ChurnConfig {
            availability: 0.3 + rng.next_f64() * 0.6,
            from_round: 1 + rng.next_u64() % 3,
        });
        let p = flsim::adversary::materialize_faults(&job, &names);
        let q = flsim::adversary::materialize_faults(&job, &names);
        for name in &names {
            for round in 1..=job.rounds {
                if p.is_down(name, round) != q.is_down(name, round) {
                    return Err("churn materialization is not deterministic".into());
                }
                if round < job.faults.churn.unwrap().from_round && p.is_down(name, round) {
                    return Err("churn fired before from_round".into());
                }
            }
        }
        Ok(())
    });
}
