//! Property-based tests on coordinator invariants (hand-rolled generator
//! harness — proptest is not vendored in this offline image). Each property
//! runs over a couple hundred seeded random cases; failures print the
//! offending seed for replay.

use flsim::aggregate::mean::{weighted_mean, ReductionOrder};
use flsim::aggregate::robust::{coordinate_median, trimmed_mean};
use flsim::consensus::{by_name, Proposal};
use flsim::data::dataset::Distribution;
use flsim::data::partition::Partition;
use flsim::data::synthetic;
use flsim::kvstore::store::{KvStore, Payload};
use flsim::topology::graph::{Overlay, TopologyKind};
use flsim::util::rng::Rng;
use flsim::util::yaml::Yaml;

/// Run `prop` over `cases` seeded cases.
fn forall(cases: u64, prop: impl Fn(&mut Rng) -> Result<(), String>) {
    for seed in 0..cases {
        let mut rng = Rng::seed_from(0xF00D + seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed at seed {seed}: {msg}");
        }
    }
}

#[test]
fn prop_partition_is_exact_cover() {
    forall(60, |rng| {
        let n = 50 + rng.below(400);
        let clients = 2 + rng.below(20);
        let dist = match rng.below(3) {
            0 => Distribution::Iid,
            1 => Distribution::Dirichlet {
                alpha: 0.1 + rng.next_f64() * 2.0,
            },
            _ => Distribution::Shards {
                shards_per_client: 1 + rng.below(3),
            },
        };
        let ds = synthetic::mnist_synth(n, rng.next_u64());
        let p = Partition::build(&ds, clients, &dist, rng);
        // Exact cover: every index assigned exactly once.
        let mut seen = vec![false; n];
        for a in &p.assignments {
            for &i in a {
                if seen[i] {
                    return Err(format!("index {i} assigned twice ({dist:?})"));
                }
                seen[i] = true;
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err(format!("not all of {n} examples assigned ({dist:?})"));
        }
        // No starving clients.
        if p.assignments.iter().any(Vec::is_empty) {
            return Err(format!("empty client under {dist:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_weighted_mean_within_hull_and_orders_agree() {
    forall(120, |rng| {
        let n = 1 + rng.below(12);
        let dim = 1 + rng.below(200);
        let models: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..dim).map(|_| rng.normal_f32() * 5.0).collect())
            .collect();
        let refs: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
        let weights: Vec<f64> = (0..n).map(|_| 0.1 + rng.next_f64() * 9.9).collect();
        let base = weighted_mean(&refs, &weights, ReductionOrder::Sequential)
            .map_err(|e| e.to_string())?;
        // Convex-hull bound per coordinate.
        for j in 0..dim {
            let lo = refs.iter().map(|p| p[j]).fold(f32::INFINITY, f32::min);
            let hi = refs.iter().map(|p| p[j]).fold(f32::NEG_INFINITY, f32::max);
            if base[j] < lo - 1e-3 || base[j] > hi + 1e-3 {
                return Err(format!("coordinate {j} out of hull"));
            }
        }
        // All reduction orders agree within fp tolerance.
        for order in ReductionOrder::ALL {
            let other = weighted_mean(&refs, &weights, order).map_err(|e| e.to_string())?;
            for j in 0..dim {
                if (other[j] - base[j]).abs() > 1e-3 {
                    return Err(format!("{order:?} diverges at {j}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_robust_aggregators_bounded_by_extremes() {
    forall(80, |rng| {
        let n = 3 + rng.below(10);
        let dim = 1 + rng.below(50);
        let models: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..dim).map(|_| rng.normal_f32() * 3.0).collect())
            .collect();
        let refs: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
        let med = coordinate_median(&refs).map_err(|e| e.to_string())?;
        let trim = (n - 1) / 2;
        let tm = trimmed_mean(&refs, trim.min((n - 1) / 2)).map_err(|e| e.to_string())?;
        for j in 0..dim {
            let lo = refs.iter().map(|p| p[j]).fold(f32::INFINITY, f32::min);
            let hi = refs.iter().map(|p| p[j]).fold(f32::NEG_INFINITY, f32::max);
            if med[j] < lo || med[j] > hi {
                return Err("median out of range".into());
            }
            if tm[j] < lo - 1e-4 || tm[j] > hi + 1e-4 {
                return Err("trimmed mean out of range".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_consensus_honest_majority_always_wins() {
    let consensus = by_name("majority_hash").unwrap();
    forall(150, |rng| {
        let honest = 2 + rng.below(4); // 2..5 honest
        let malicious = 1 + rng.below(honest - 1); // strictly fewer malicious
        let dim = 1 + rng.below(64);
        let honest_params: Vec<f32> = (0..dim).map(|_| rng.normal_f32()).collect();
        let mut proposals = Vec::new();
        for m in 0..malicious {
            // Each attacker submits its own (distinct) poison.
            let poison: Vec<f32> = honest_params
                .iter()
                .map(|&v| -v + m as f32 + rng.normal_f32())
                .collect();
            proposals.push(Proposal::new(format!("mal_{m}"), poison));
        }
        for h in 0..honest {
            proposals.push(Proposal::new(format!("h_{h}"), honest_params.clone()));
        }
        let d = consensus.decide(&proposals, rng).map_err(|e| e.to_string())?;
        if proposals[d.winner].params != honest_params {
            return Err(format!(
                "poison won with {malicious} malicious vs {honest} honest"
            ));
        }
        if !d.decisive {
            return Err("honest majority should be decisive".into());
        }
        Ok(())
    });
}

#[test]
fn prop_overlay_invariants_all_topologies() {
    forall(60, |rng| {
        let n = 2 + rng.below(30);
        let w = 1 + rng.below(4);
        for kind in [
            TopologyKind::ClientServer,
            TopologyKind::Hierarchical,
            TopologyKind::FullyConnected,
            TopologyKind::Ring,
        ] {
            let o = Overlay::build(kind, n, w);
            o.validate().map_err(|e| format!("{kind:?}: {e}"))?;
            if o.clients().is_empty() {
                return Err(format!("{kind:?}: no clients"));
            }
            // Edges reference known nodes both ways; neighbors symmetric for
            // undirected-by-construction topologies.
            for (a, b) in &o.edges {
                if a == b {
                    return Err(format!("{kind:?}: self-loop"));
                }
                if !o.roles.contains_key(a) || !o.roles.contains_key(b) {
                    return Err(format!("{kind:?}: dangling edge"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_kvstore_conservation_of_bytes() {
    forall(80, |rng| {
        let mut kv = KvStore::new();
        let nodes = 2 + rng.below(8);
        let mut expected_total = 0u64;
        for round in 0..1 + rng.below(5) as u64 {
            for i in 0..nodes {
                let len = rng.below(500);
                let payload = Payload::Params((0..len).map(|_| rng.normal_f32()).collect());
                expected_total += payload.wire_bytes();
                kv.publish("t", &format!("n{i}"), round, payload);
            }
            // One reader drains the round.
            let msgs = kv.fetch_round("t", round, "reader");
            if msgs.len() != nodes {
                return Err("lost messages".into());
            }
            for m in &msgs {
                expected_total += m.payload.wire_bytes();
            }
        }
        if kv.total_bytes() != expected_total {
            return Err(format!(
                "byte conservation broken: {} != {expected_total}",
                kv.total_bytes()
            ));
        }
        // Egress of writers == ingress of reader.
        let out: u64 = (0..nodes)
            .map(|i| kv.traffic(&format!("n{i}")).bytes_out)
            .sum();
        let inn = kv.traffic("reader").bytes_in;
        if out != inn {
            return Err(format!("egress {out} != ingress {inn}"));
        }
        Ok(())
    });
}

#[test]
fn prop_yaml_scalar_roundtrip() {
    forall(100, |rng| {
        // Random flat configs stay parseable and value-stable.
        let n_keys = 1 + rng.below(10);
        let mut src = String::new();
        let mut expect = Vec::new();
        for k in 0..n_keys {
            match rng.below(3) {
                0 => {
                    let v = rng.below(100000) as i64;
                    src.push_str(&format!("k{k}: {v}\n"));
                    expect.push((format!("k{k}"), Yaml::Int(v)));
                }
                1 => {
                    let v = (rng.next_f64() * 100.0 * 8.0).round() / 8.0; // exact in binary
                    src.push_str(&format!("k{k}: {v:?}\n"));
                    expect.push((format!("k{k}"), Yaml::Float(v)));
                }
                _ => {
                    src.push_str(&format!("k{k}: value_{k}\n"));
                    expect.push((format!("k{k}"), Yaml::Str(format!("value_{k}"))));
                }
            }
        }
        let y = Yaml::parse(&src).map_err(|e| e.to_string())?;
        for (k, v) in expect {
            let got = y.get(&k).ok_or(format!("missing {k}"))?;
            if got != &v {
                return Err(format!("{k}: {got:?} != {v:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_rng_streams_never_collide() {
    forall(50, |rng| {
        let root = Rng::seed_from(rng.next_u64());
        let mut a = root.derive("purpose_a", 0);
        let mut b = root.derive("purpose_b", 0);
        let mut c = root.derive("purpose_a", 1);
        let (va, vb, vc) = (a.next_u64(), b.next_u64(), c.next_u64());
        if va == vb || va == vc || vb == vc {
            return Err("derived streams collided".into());
        }
        Ok(())
    });
}
