//! The campaign engine's contracts (the acceptance criteria of the
//! campaign PR):
//!
//! * grid expansion is deterministic — sorted axis order, listed value
//!   order, stable cell count, duplicate cells deduplicated;
//! * cache keys are stable across YAML field reordering and independent of
//!   wall-clock knobs (`parallelism`, `campaign.jobs`);
//! * run → resume: an immediate second run of an unchanged campaign hits
//!   the result cache for every cell and reproduces a **byte-identical**
//!   campaign report;
//! * a failing cell never discards completed cells — they persist to the
//!   store as they finish and are cache hits on the retry.

use std::path::PathBuf;

use flsim::campaign::{self, CampaignReport, CampaignSpec, ResultStore};
use flsim::config::job::JobConfig;
use flsim::runtime::pjrt::Runtime;
use flsim::util::yaml::Yaml;

fn tmp_store(tag: &str) -> (ResultStore, PathBuf) {
    let dir = std::env::temp_dir().join(format!(
        "flsim_campaign_{tag}_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    (ResultStore::open(&dir).unwrap(), dir)
}

fn tiny_base() -> JobConfig {
    let mut j = JobConfig::default_cnn("fedavg");
    j.name = "tiny".into();
    j.rounds = 2;
    j.dataset.n = 600;
    j.n_clients = 4;
    j
}

/// A 2×2 strategy × seed sweep over the tiny base.
fn two_by_two(jobs: usize) -> CampaignSpec {
    CampaignSpec::builder("twobytwo", tiny_base())
        .axis_strs("strategy", &["fedavg", "fedprox"])
        .axis_ints("seed", &[1, 2])
        .jobs(jobs)
        .build()
}

// ---------------------------------------------------------------------------
// Pure expansion / hashing contracts (no engine needed).
// ---------------------------------------------------------------------------

const SPEC_A: &str = r#"
campaign:
  name: order
axes:
  seed: [1, 2]
  strategy: [fedavg, fedprox]
job:
  name: base
  rounds: 2
  seed: 9
dataset:
  name: cifar10_synth
  n: 600
  distribution:
    kind: dirichlet
    alpha: 0.5
strategy:
  name: fedavg
  backend: cnn
  train_params:
    learning_rate: 0.01
    local_epochs: 5
topology:
  kind: client_server
  clients: 4
  workers: 1
"#;

/// The same campaign with every reorderable construct reordered: axes
/// listed in the other order, job/dataset/strategy/topology sections and
/// their fields shuffled.
const SPEC_B: &str = r#"
topology:
  workers: 1
  clients: 4
  kind: client_server
strategy:
  train_params:
    local_epochs: 5
    learning_rate: 0.01
  backend: cnn
  name: fedavg
dataset:
  distribution:
    alpha: 0.5
    kind: dirichlet
  n: 600
  name: cifar10_synth
axes:
  strategy: [fedavg, fedprox]
  seed: [1, 2]
job:
  seed: 9
  rounds: 2
  name: base
campaign:
  name: order
"#;

#[test]
fn grid_expansion_is_deterministic() {
    let spec = CampaignSpec::from_yaml_str(SPEC_A).unwrap();
    let cells = campaign::expand(&spec).unwrap();
    assert_eq!(cells.len(), 4);
    let names: Vec<&str> = cells.iter().map(|c| c.name.as_str()).collect();
    // Axes expand in sorted name order (seed before strategy), values in
    // listed order, last axis fastest.
    assert_eq!(names, ["seed1_fedavg", "seed1_fedprox", "seed2_fedavg", "seed2_fedprox"]);
    // A second expansion is identical.
    let again = campaign::expand(&spec).unwrap();
    assert_eq!(
        cells.iter().map(|c| &c.key).collect::<Vec<_>>(),
        again.iter().map(|c| &c.key).collect::<Vec<_>>()
    );
}

#[test]
fn cache_keys_stable_across_yaml_field_reordering() {
    let a = campaign::expand(&CampaignSpec::from_yaml_str(SPEC_A).unwrap()).unwrap();
    let b = campaign::expand(&CampaignSpec::from_yaml_str(SPEC_B).unwrap()).unwrap();
    assert_eq!(a.len(), b.len());
    for (ca, cb) in a.iter().zip(&b) {
        assert_eq!(ca.name, cb.name);
        assert_eq!(
            ca.key, cb.key,
            "cell '{}': key must not depend on YAML field order",
            ca.name
        );
    }
}

#[test]
fn cache_keys_ignore_schedule_knobs() {
    let cells_at = |parallelism: usize, jobs: usize| {
        let mut spec = two_by_two(jobs);
        spec.base.parallelism = parallelism;
        campaign::expand(&spec).unwrap()
    };
    let a = cells_at(1, 1);
    let b = cells_at(8, 4);
    for (ca, cb) in a.iter().zip(&b) {
        assert_eq!(ca.key, cb.key, "schedule knobs must not change cell keys");
    }
}

#[test]
fn duplicate_cells_dedup_across_grid_and_explicit() {
    let spec = CampaignSpec::builder("dup", tiny_base())
        .axis_strs("strategy", &["fedavg", "fedavg", "fedprox"])
        .cell("fedprox", vec![("strategy", "fedprox".into())])
        .build();
    let cells = campaign::expand(&spec).unwrap();
    assert_eq!(cells.len(), 2);
    // ... while a name clash between *different* configs is an error.
    let clash = CampaignSpec::builder("clash", tiny_base())
        .cell("same", vec![("seed", Yaml::Int(1))])
        .cell("same", vec![("seed", Yaml::Int(2))])
        .build();
    assert!(campaign::expand(&clash).is_err());
}

// ---------------------------------------------------------------------------
// Engine-backed: run → cached resume → byte-identical report.
// ---------------------------------------------------------------------------

#[test]
fn campaign_resumes_from_cache_with_byte_identical_report() {
    let (store, dir) = tmp_store("resume");
    let rt = Runtime::shared("artifacts").unwrap();
    let spec = two_by_two(2);

    let first = campaign::run(rt.clone(), &spec, &store).unwrap();
    assert_eq!(first.cells.len(), 4);
    assert!(first.failed().is_empty(), "{:?}", first.failed());
    assert!(
        first.cells.iter().all(|c| !c.cached),
        "first run must execute every cell"
    );
    for c in &first.cells {
        assert!(store.contains(&c.cell.key), "cell {} not persisted", c.cell.name);
    }

    // Immediate re-run: every cell must be a cache hit — no execution.
    let execs_before = rt.stats().executions;
    let second = campaign::run(rt.clone(), &spec, &store).unwrap();
    assert!(second.all_cached(), "re-run must hit the cache for every cell");
    assert_eq!(
        rt.stats().executions,
        execs_before,
        "a fully-cached campaign must not touch the engine"
    );

    // ... and the resumed campaign report is byte-identical.
    let rep1 = CampaignReport::from_outcome(&first);
    let rep2 = CampaignReport::from_outcome(&second);
    assert_eq!(rep1.to_csv(), rep2.to_csv());
    assert_eq!(rep1.to_json().to_string(), rep2.to_json().to_string());

    // Editing one axis value re-runs only the changed cells.
    let mut edited = spec.clone();
    edited.axes.insert("seed".into(), vec![Yaml::Int(1), Yaml::Int(3)]);
    let third = campaign::run(rt, &edited, &store).unwrap();
    let cached: Vec<&str> = third
        .cells
        .iter()
        .filter(|c| c.cached)
        .map(|c| c.cell.name.as_str())
        .collect();
    assert_eq!(cached, ["seed1_fedavg", "seed1_fedprox"]);

    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn schedule_does_not_change_results() {
    let (store_serial, dir_a) = tmp_store("sched_serial");
    let (store_parallel, dir_b) = tmp_store("sched_parallel");
    let rt = Runtime::shared("artifacts").unwrap();

    let serial = campaign::run(rt.clone(), &two_by_two(1), &store_serial).unwrap();
    let parallel = campaign::run(rt, &two_by_two(4), &store_parallel).unwrap();
    assert!(serial.failed().is_empty() && parallel.failed().is_empty());
    for (a, b) in serial.cells.iter().zip(&parallel.cells) {
        assert_eq!(a.cell.name, b.cell.name);
        assert_eq!(a.cell.key, b.cell.key);
        let (ra, rb) = (a.report.as_ref().unwrap(), b.report.as_ref().unwrap());
        for (ma, mb) in ra.rounds.iter().zip(&rb.rounds) {
            assert_eq!(ma.model_hash, mb.model_hash, "cell {}", a.cell.name);
            assert_eq!(ma.net_bytes, mb.net_bytes, "cell {}", a.cell.name);
            assert_eq!(
                ma.test_accuracy.to_bits(),
                mb.test_accuracy.to_bits(),
                "cell {}",
                a.cell.name
            );
        }
    }

    std::fs::remove_dir_all(&dir_a).unwrap();
    std::fs::remove_dir_all(&dir_b).unwrap();
}

#[test]
fn failing_cell_persists_completed_cells() {
    let (store, dir) = tmp_store("failpersist");
    let rt = Runtime::shared("artifacts").unwrap();

    let spec = CampaignSpec::builder("partial", tiny_base())
        .cell("good", vec![("seed", Yaml::Int(1))])
        .cell("bad", vec![("backend", "no_such_backend".into())])
        .build();

    let outcome = campaign::run(rt.clone(), &spec, &store).unwrap();
    assert_eq!(outcome.cells.len(), 2);
    let good = outcome.cells.iter().find(|c| c.cell.name == "good").unwrap();
    let bad = outcome.cells.iter().find(|c| c.cell.name == "bad").unwrap();
    assert!(good.report.is_some() && good.error.is_none());
    assert!(bad.report.is_none() && bad.error.is_some());
    assert!(
        store.contains(&good.cell.key),
        "completed cell must persist despite the failure"
    );
    assert!(!store.contains(&bad.cell.key));

    // The retry resumes the completed cell from cache and re-attempts the
    // failed one.
    let retry = campaign::run(rt, &spec, &store).unwrap();
    let good2 = retry.cells.iter().find(|c| c.cell.name == "good").unwrap();
    assert!(good2.cached);
    assert!(retry.cells.iter().any(|c| c.error.is_some()));

    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------------
// The fig11-style sweep as a single campaign spec (acceptance criterion).
// ---------------------------------------------------------------------------

#[test]
fn fig11_style_sweep_runs_and_resumes_as_one_spec() {
    let (store, dir) = tmp_store("fig11");
    let rt = Runtime::shared("artifacts").unwrap();

    let mut base = tiny_base();
    base.rounds = 1;
    let spec = CampaignSpec::builder("fig11_mini", base)
        .cell("client_server", vec![])
        .cell(
            "hierarchical",
            vec![("topology", "hierarchical".into()), ("workers", Yaml::Int(3))],
        )
        .cell("decentralized", vec![("strategy", "fedstellar".into())])
        .jobs(2)
        .build();

    let first = campaign::run(rt.clone(), &spec, &store).unwrap();
    assert!(first.failed().is_empty(), "{:?}", first.failed());
    let names: Vec<&str> = first.cells.iter().map(|c| c.cell.name.as_str()).collect();
    assert_eq!(names, ["client_server", "hierarchical", "decentralized"]);

    let second = campaign::run(rt, &spec, &store).unwrap();
    assert!(second.all_cached());
    assert_eq!(
        CampaignReport::from_outcome(&first).to_csv(),
        CampaignReport::from_outcome(&second).to_csv()
    );
    assert_eq!(
        CampaignReport::from_outcome(&first).to_json().to_string(),
        CampaignReport::from_outcome(&second).to_json().to_string()
    );

    std::fs::remove_dir_all(&dir).unwrap();
}
