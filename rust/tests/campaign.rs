//! The campaign engine's contracts (the acceptance criteria of the
//! campaign PR):
//!
//! * grid expansion is deterministic — sorted axis order, listed value
//!   order, stable cell count, duplicate cells deduplicated;
//! * cache keys are stable across YAML field reordering and independent of
//!   wall-clock knobs (`parallelism`, `campaign.jobs`);
//! * run → resume: an immediate second run of an unchanged campaign hits
//!   the result cache for every cell and reproduces a **byte-identical**
//!   campaign report;
//! * a failing cell never discards completed cells — they persist to the
//!   store as they finish and are cache hits on the retry;
//! * cancellation is clean: a run stopped by budget or token returns a
//!   bitwise *prefix* of the full run, and no stop path leaves torn
//!   (`.tmp`) entries in the result store;
//! * the ASHA scheduler executes strictly fewer rounds than the grid,
//!   promotes a worker-count-independent cell set, and replays its rung
//!   decisions entirely from cache on a re-run.

use std::path::{Path, PathBuf};

use flsim::campaign::{self, CampaignReport, CampaignSpec, ResultStore};
use flsim::config::job::JobConfig;
use flsim::controller::{CancelToken, FaultPlan};
use flsim::orchestrator::{Orchestrator, RunControl, RunOptions};
use flsim::runtime::pjrt::Runtime;
use flsim::util::yaml::Yaml;

fn tmp_store(tag: &str) -> (ResultStore, PathBuf) {
    let dir = std::env::temp_dir().join(format!(
        "flsim_campaign_{tag}_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    (ResultStore::open(&dir).unwrap(), dir)
}

fn tiny_base() -> JobConfig {
    let mut j = JobConfig::default_cnn("fedavg");
    j.name = "tiny".into();
    j.rounds = 2;
    j.dataset.n = 600;
    j.n_clients = 4;
    j
}

/// A 2×2 strategy × seed sweep over the tiny base.
fn two_by_two(jobs: usize) -> CampaignSpec {
    CampaignSpec::builder("twobytwo", tiny_base())
        .axis_strs("strategy", &["fedavg", "fedprox"])
        .axis_ints("seed", &[1, 2])
        .jobs(jobs)
        .build()
}

// ---------------------------------------------------------------------------
// Pure expansion / hashing contracts (no engine needed).
// ---------------------------------------------------------------------------

const SPEC_A: &str = r#"
campaign:
  name: order
axes:
  seed: [1, 2]
  strategy: [fedavg, fedprox]
job:
  name: base
  rounds: 2
  seed: 9
dataset:
  name: cifar10_synth
  n: 600
  distribution:
    kind: dirichlet
    alpha: 0.5
strategy:
  name: fedavg
  backend: cnn
  train_params:
    learning_rate: 0.01
    local_epochs: 5
topology:
  kind: client_server
  clients: 4
  workers: 1
"#;

/// The same campaign with every reorderable construct reordered: axes
/// listed in the other order, job/dataset/strategy/topology sections and
/// their fields shuffled.
const SPEC_B: &str = r#"
topology:
  workers: 1
  clients: 4
  kind: client_server
strategy:
  train_params:
    local_epochs: 5
    learning_rate: 0.01
  backend: cnn
  name: fedavg
dataset:
  distribution:
    alpha: 0.5
    kind: dirichlet
  n: 600
  name: cifar10_synth
axes:
  strategy: [fedavg, fedprox]
  seed: [1, 2]
job:
  seed: 9
  rounds: 2
  name: base
campaign:
  name: order
"#;

#[test]
fn grid_expansion_is_deterministic() {
    let spec = CampaignSpec::from_yaml_str(SPEC_A).unwrap();
    let cells = campaign::expand(&spec).unwrap();
    assert_eq!(cells.len(), 4);
    let names: Vec<&str> = cells.iter().map(|c| c.name.as_str()).collect();
    // Axes expand in sorted name order (seed before strategy), values in
    // listed order, last axis fastest.
    assert_eq!(names, ["seed1_fedavg", "seed1_fedprox", "seed2_fedavg", "seed2_fedprox"]);
    // A second expansion is identical.
    let again = campaign::expand(&spec).unwrap();
    assert_eq!(
        cells.iter().map(|c| &c.key).collect::<Vec<_>>(),
        again.iter().map(|c| &c.key).collect::<Vec<_>>()
    );
}

#[test]
fn cache_keys_stable_across_yaml_field_reordering() {
    let a = campaign::expand(&CampaignSpec::from_yaml_str(SPEC_A).unwrap()).unwrap();
    let b = campaign::expand(&CampaignSpec::from_yaml_str(SPEC_B).unwrap()).unwrap();
    assert_eq!(a.len(), b.len());
    for (ca, cb) in a.iter().zip(&b) {
        assert_eq!(ca.name, cb.name);
        assert_eq!(
            ca.key, cb.key,
            "cell '{}': key must not depend on YAML field order",
            ca.name
        );
    }
}

#[test]
fn cache_keys_ignore_schedule_knobs() {
    let cells_at = |parallelism: usize, jobs: usize| {
        let mut spec = two_by_two(jobs);
        spec.base.parallelism = parallelism;
        campaign::expand(&spec).unwrap()
    };
    let a = cells_at(1, 1);
    let b = cells_at(8, 4);
    for (ca, cb) in a.iter().zip(&b) {
        assert_eq!(ca.key, cb.key, "schedule knobs must not change cell keys");
    }
}

#[test]
fn duplicate_cells_dedup_across_grid_and_explicit() {
    let spec = CampaignSpec::builder("dup", tiny_base())
        .axis_strs("strategy", &["fedavg", "fedavg", "fedprox"])
        .cell("fedprox", vec![("strategy", "fedprox".into())])
        .build();
    let cells = campaign::expand(&spec).unwrap();
    assert_eq!(cells.len(), 2);
    // ... while a name clash between *different* configs is an error.
    let clash = CampaignSpec::builder("clash", tiny_base())
        .cell("same", vec![("seed", Yaml::Int(1))])
        .cell("same", vec![("seed", Yaml::Int(2))])
        .build();
    assert!(campaign::expand(&clash).is_err());
}

// ---------------------------------------------------------------------------
// Engine-backed: run → cached resume → byte-identical report.
// ---------------------------------------------------------------------------

#[test]
fn campaign_resumes_from_cache_with_byte_identical_report() {
    let (store, dir) = tmp_store("resume");
    let rt = Runtime::shared("artifacts").unwrap();
    let spec = two_by_two(2);

    let first = campaign::run(rt.clone(), &spec, &store).unwrap();
    assert_eq!(first.cells.len(), 4);
    assert!(first.failed().is_empty(), "{:?}", first.failed());
    assert!(
        first.cells.iter().all(|c| !c.cached),
        "first run must execute every cell"
    );
    for c in &first.cells {
        assert!(store.contains(&c.cell.key), "cell {} not persisted", c.cell.name);
    }

    // Immediate re-run: every cell must be a cache hit — no execution.
    let execs_before = rt.stats().executions;
    let second = campaign::run(rt.clone(), &spec, &store).unwrap();
    assert!(second.all_cached(), "re-run must hit the cache for every cell");
    assert_eq!(
        rt.stats().executions,
        execs_before,
        "a fully-cached campaign must not touch the engine"
    );

    // ... and the resumed campaign report is byte-identical.
    let rep1 = CampaignReport::from_outcome(&first);
    let rep2 = CampaignReport::from_outcome(&second);
    assert_eq!(rep1.to_csv(), rep2.to_csv());
    assert_eq!(rep1.to_json().to_string(), rep2.to_json().to_string());

    // Editing one axis value re-runs only the changed cells.
    let mut edited = spec.clone();
    edited.axes.insert("seed".into(), vec![Yaml::Int(1), Yaml::Int(3)]);
    let third = campaign::run(rt, &edited, &store).unwrap();
    let cached: Vec<&str> = third
        .cells
        .iter()
        .filter(|c| c.cached)
        .map(|c| c.cell.name.as_str())
        .collect();
    assert_eq!(cached, ["seed1_fedavg", "seed1_fedprox"]);

    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn schedule_does_not_change_results() {
    let (store_serial, dir_a) = tmp_store("sched_serial");
    let (store_parallel, dir_b) = tmp_store("sched_parallel");
    let rt = Runtime::shared("artifacts").unwrap();

    let serial = campaign::run(rt.clone(), &two_by_two(1), &store_serial).unwrap();
    let parallel = campaign::run(rt, &two_by_two(4), &store_parallel).unwrap();
    assert!(serial.failed().is_empty() && parallel.failed().is_empty());
    for (a, b) in serial.cells.iter().zip(&parallel.cells) {
        assert_eq!(a.cell.name, b.cell.name);
        assert_eq!(a.cell.key, b.cell.key);
        let (ra, rb) = (a.report.as_ref().unwrap(), b.report.as_ref().unwrap());
        for (ma, mb) in ra.rounds.iter().zip(&rb.rounds) {
            assert_eq!(ma.model_hash, mb.model_hash, "cell {}", a.cell.name);
            assert_eq!(ma.net_bytes, mb.net_bytes, "cell {}", a.cell.name);
            assert_eq!(
                ma.test_accuracy.to_bits(),
                mb.test_accuracy.to_bits(),
                "cell {}",
                a.cell.name
            );
        }
    }

    std::fs::remove_dir_all(&dir_a).unwrap();
    std::fs::remove_dir_all(&dir_b).unwrap();
}

#[test]
fn failing_cell_persists_completed_cells() {
    let (store, dir) = tmp_store("failpersist");
    let rt = Runtime::shared("artifacts").unwrap();

    let spec = CampaignSpec::builder("partial", tiny_base())
        .cell("good", vec![("seed", Yaml::Int(1))])
        .cell("bad", vec![("backend", "no_such_backend".into())])
        .build();

    let outcome = campaign::run(rt.clone(), &spec, &store).unwrap();
    assert_eq!(outcome.cells.len(), 2);
    let good = outcome.cells.iter().find(|c| c.cell.name == "good").unwrap();
    let bad = outcome.cells.iter().find(|c| c.cell.name == "bad").unwrap();
    assert!(good.report.is_some() && good.error.is_none());
    assert!(bad.report.is_none() && bad.error.is_some());
    assert!(
        store.contains(&good.cell.key),
        "completed cell must persist despite the failure"
    );
    assert!(!store.contains(&bad.cell.key));

    // The retry resumes the completed cell from cache and re-attempts the
    // failed one.
    let retry = campaign::run(rt, &spec, &store).unwrap();
    let good2 = retry.cells.iter().find(|c| c.cell.name == "good").unwrap();
    assert!(good2.cached);
    assert!(retry.cells.iter().any(|c| c.error.is_some()));

    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------------
// The fig11-style sweep as a single campaign spec (acceptance criterion).
// ---------------------------------------------------------------------------

#[test]
fn fig11_style_sweep_runs_and_resumes_as_one_spec() {
    let (store, dir) = tmp_store("fig11");
    let rt = Runtime::shared("artifacts").unwrap();

    let mut base = tiny_base();
    base.rounds = 1;
    let spec = CampaignSpec::builder("fig11_mini", base)
        .cell("client_server", vec![])
        .cell(
            "hierarchical",
            vec![("topology", "hierarchical".into()), ("workers", Yaml::Int(3))],
        )
        .cell("decentralized", vec![("strategy", "fedstellar".into())])
        .jobs(2)
        .build();

    let first = campaign::run(rt.clone(), &spec, &store).unwrap();
    assert!(first.failed().is_empty(), "{:?}", first.failed());
    let names: Vec<&str> = first.cells.iter().map(|c| c.cell.name.as_str()).collect();
    assert_eq!(names, ["client_server", "hierarchical", "decentralized"]);

    let second = campaign::run(rt, &spec, &store).unwrap();
    assert!(second.all_cached());
    assert_eq!(
        CampaignReport::from_outcome(&first).to_csv(),
        CampaignReport::from_outcome(&second).to_csv()
    );
    assert_eq!(
        CampaignReport::from_outcome(&first).to_json().to_string(),
        CampaignReport::from_outcome(&second).to_json().to_string()
    );

    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------------
// Cancellation correctness: stopped runs are bitwise prefixes; no stop path
// leaves torn store entries.
// ---------------------------------------------------------------------------

/// Every per-round field two runs must agree on bitwise.
fn assert_rounds_bitwise_equal(
    a: &[flsim::metrics::report::RoundMetrics],
    b: &[flsim::metrics::report::RoundMetrics],
    what: &str,
) {
    assert_eq!(a.len(), b.len(), "{what}: round count");
    for (ma, mb) in a.iter().zip(b) {
        let r = ma.round;
        assert_eq!(ma.round, mb.round, "{what}");
        assert_eq!(ma.model_hash, mb.model_hash, "{what} round {r}");
        assert_eq!(ma.net_bytes, mb.net_bytes, "{what} round {r}");
        assert_eq!(ma.test_accuracy.to_bits(), mb.test_accuracy.to_bits(), "{what} round {r}");
        assert_eq!(ma.test_loss.to_bits(), mb.test_loss.to_bits(), "{what} round {r}");
        assert_eq!(ma.train_loss.to_bits(), mb.train_loss.to_bits(), "{what} round {r}");
        assert_eq!(ma.sim_round_secs.to_bits(), mb.sim_round_secs.to_bits(), "{what} round {r}");
    }
}

#[test]
fn stopped_runs_are_bitwise_prefixes_of_the_full_run() {
    let rt = Runtime::shared("artifacts").unwrap();
    let mut job = tiny_base();
    job.rounds = 4;

    let full = Orchestrator::new(rt.clone()).run(&job, RunOptions::default()).unwrap();
    assert!(!full.stopped_early);
    assert_eq!(full.rounds_completed(), 4);

    // Budget stop at round 2: exactly the first two rounds, bit for bit.
    let budgeted = Orchestrator::new(rt.clone())
        .run(&job, RunOptions::default().control(RunControl::budget(2)))
        .unwrap();
    assert!(budgeted.stopped_early);
    assert_eq!(budgeted.rounds_completed(), 2);
    assert_rounds_bitwise_equal(&budgeted.rounds, &full.rounds[..2], "budget stop");

    // Cooperative cancel fired from the per-round metric sink after round
    // 3 commits: the loop observes it at the round boundary.
    let cancel = CancelToken::new();
    let cancel_in_sink = cancel.clone();
    let ctl = RunControl {
        cancel: cancel.clone(),
        round_budget: None,
        on_round: Some(Box::new(move |m| {
            if m.round == 3 {
                cancel_in_sink.cancel();
            }
        })),
    };
    let cancelled = Orchestrator::new(rt.clone())
        .run(&job, RunOptions::default().control(ctl))
        .unwrap();
    assert!(cancelled.stopped_early);
    assert_eq!(cancelled.rounds_completed(), 3);
    assert_rounds_bitwise_equal(&cancelled.rounds, &full.rounds[..3], "cancel stop");

    // A pre-cancelled token yields a valid zero-round partial report.
    let pre = CancelToken::new();
    pre.cancel();
    let ctl = RunControl {
        cancel: pre,
        ..RunControl::default()
    };
    let empty = Orchestrator::new(rt)
        .run(&job, RunOptions::default().control(ctl))
        .unwrap();
    assert!(empty.stopped_early);
    assert_eq!(empty.rounds_completed(), 0);
}

/// Walk a store directory asserting no `.tmp` residue anywhere.
fn assert_no_tmp_residue(dir: &Path) {
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        for entry in std::fs::read_dir(&d).unwrap().flatten() {
            let p = entry.path();
            if p.is_dir() {
                stack.push(p);
            } else {
                assert!(
                    p.extension().map(|e| e != "tmp").unwrap_or(true),
                    "torn store entry left behind: {p:?}"
                );
            }
        }
    }
}

#[test]
fn cancelled_campaign_leaves_no_torn_store_entries() {
    let (store, dir) = tmp_store("torn");
    let rt = Runtime::shared("artifacts").unwrap();
    let mut job = tiny_base();
    job.rounds = 3;

    // A cancelled run whose partial is persisted: the tmp+rename write
    // must leave exactly the committed entry.
    let cancel = CancelToken::new();
    let cancel_in_sink = cancel.clone();
    let ctl = RunControl {
        cancel,
        round_budget: None,
        on_round: Some(Box::new(move |m| {
            if m.round == 1 {
                cancel_in_sink.cancel();
            }
        })),
    };
    let partial = Orchestrator::new(rt.clone())
        .run(&job, RunOptions::default().control(ctl))
        .unwrap();
    assert!(partial.stopped_early);
    let key = campaign::cell_key(&job);
    assert!(store
        .commit(
            &key,
            campaign::CellOutcome::new(&job, &partial)
                .cell("cancelled")
                .campaign("camp"),
        )
        .unwrap());
    assert_no_tmp_residue(&dir);
    // The committed partial loads cleanly at its depth.
    assert_eq!(store.get_at_least(&key, 1).unwrap().rounds_completed(), 1);
    assert!(store.get(&key).is_none(), "partial must not read as complete");

    // An ASHA campaign (many puts + partial puts across rungs) is equally
    // clean, and every surviving entry is loadable.
    let spec = eight_cell_asha(2);
    let outcome = campaign::run(rt, &spec, &store).unwrap();
    assert!(outcome.failed().is_empty(), "{:?}", outcome.failure_lines());
    assert_no_tmp_residue(&dir);
    for (key, _, _) in store.entries() {
        assert!(
            store.get_at_least(&key, 1).is_some(),
            "unloadable store entry {key}"
        );
    }

    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------------
// The ASHA scheduler's contracts.
// ---------------------------------------------------------------------------

/// A 2×2×2 sweep (strategy × learning-rate × seed, 4 rounds each) under
/// ASHA with eta 2 and a one-round first rung: budgets 1, 2, 4.
fn eight_cell_asha(jobs: usize) -> CampaignSpec {
    let mut base = tiny_base();
    base.name = "asha8".into();
    base.rounds = 4;
    CampaignSpec::builder("asha8", base)
        .axis_strs("strategy", &["fedavg", "fedprox"])
        .axis_ints("seed", &[1, 2])
        .axis("learning_rate", vec![Yaml::Float(0.01), Yaml::Float(0.02)])
        .jobs(jobs)
        .asha(2, 1)
        .build()
}

#[test]
fn asha_runs_fewer_rounds_and_promotes_schedule_invariantly() {
    let (store_a, dir_a) = tmp_store("asha_serial");
    let (store_b, dir_b) = tmp_store("asha_parallel");
    let (store_g, dir_g) = tmp_store("asha_grid");
    let rt = Runtime::shared("artifacts").unwrap();

    // The identical grid without the scheduler, for the budget comparison
    // and the prefix check.
    let mut grid_spec = eight_cell_asha(2);
    grid_spec.scheduler = flsim::campaign::SchedulerSpec::default();
    let grid = campaign::run(rt.clone(), &grid_spec, &store_g).unwrap();
    assert!(grid.failed().is_empty(), "{:?}", grid.failure_lines());
    assert_eq!(grid.cells.len(), 8);
    assert_eq!(grid.total_rounds(), 32);

    let serial = campaign::run(rt.clone(), &eight_cell_asha(1), &store_a).unwrap();
    let parallel = campaign::run(rt.clone(), &eight_cell_asha(4), &store_b).unwrap();
    for outcome in [&serial, &parallel] {
        assert!(outcome.failed().is_empty(), "{:?}", outcome.failure_lines());
        assert_eq!(outcome.cells.len(), 8);
        // Rung math: 8×1 + 4×1 + 2×2 = 16 rounds, half the grid's 32.
        assert_eq!(outcome.total_rounds(), 16);
        assert!(outcome.total_rounds() < grid.total_rounds());
        assert_eq!(outcome.stopped_early().len(), 6);
    }

    // The promoted set — which cells survived to which depth — is a pure
    // function of (spec, seed): identical at any worker count, down to the
    // per-round metrics.
    for (a, b) in serial.cells.iter().zip(&parallel.cells) {
        assert_eq!(a.cell.name, b.cell.name);
        let (ra, rb) = (a.report.as_ref().unwrap(), b.report.as_ref().unwrap());
        assert_eq!(
            ra.stopped_early,
            rb.stopped_early,
            "cell {} promoted under one schedule but not the other",
            a.cell.name
        );
        assert_rounds_bitwise_equal(&ra.rounds, &rb.rounds, &a.cell.name);
    }

    // Every cell's (possibly partial) series is a bitwise prefix of the
    // same cell run to completion by the grid.
    for (a, g) in serial.cells.iter().zip(&grid.cells) {
        assert_eq!(a.cell.key, g.cell.key);
        let (ra, rg) = (a.report.as_ref().unwrap(), g.report.as_ref().unwrap());
        let n = ra.rounds.len();
        assert_rounds_bitwise_equal(&ra.rounds, &rg.rounds[..n], &a.cell.name);
    }

    std::fs::remove_dir_all(&dir_a).unwrap();
    std::fs::remove_dir_all(&dir_b).unwrap();
    std::fs::remove_dir_all(&dir_g).unwrap();
}

#[test]
fn asha_rerun_replays_rung_decisions_from_cache() {
    let (store, dir) = tmp_store("asha_replay");
    let rt = Runtime::shared("artifacts").unwrap();
    let spec = eight_cell_asha(2);

    let first = campaign::run(rt.clone(), &spec, &store).unwrap();
    assert!(first.failed().is_empty(), "{:?}", first.failure_lines());
    assert!(!first.stopped_early().is_empty());

    // Re-run: every rung decision replays from stored (partial and
    // complete) entries — zero engine executions, byte-identical report.
    let execs_before = rt.stats().executions;
    let second = campaign::run(rt.clone(), &spec, &store).unwrap();
    assert!(second.all_cached(), "asha re-run must replay from cache");
    assert_eq!(
        rt.stats().executions,
        execs_before,
        "a fully-cached asha campaign must not touch the engine"
    );
    assert_eq!(
        CampaignReport::from_outcome(&first).to_csv(),
        CampaignReport::from_outcome(&second).to_csv()
    );
    assert_eq!(
        CampaignReport::from_outcome(&first).to_json().to_string(),
        CampaignReport::from_outcome(&second).to_json().to_string()
    );

    // Promoting stopped cells deeper (the grid runs everything to the full
    // budget) re-runs exactly the rung-stopped cells and *upgrades* their
    // entries; the subsequent asha re-run is then still fully cached.
    let mut grid_spec = spec.clone();
    grid_spec.scheduler = flsim::campaign::SchedulerSpec::default();
    let grid = campaign::run(rt.clone(), &grid_spec, &store).unwrap();
    assert!(grid.failed().is_empty(), "{:?}", grid.failure_lines());
    let cached: Vec<&str> = grid
        .cells
        .iter()
        .filter(|c| c.cached)
        .map(|c| c.cell.name.as_str())
        .collect();
    let promoted: Vec<&str> = first
        .cells
        .iter()
        .filter(|c| !c.report.as_ref().unwrap().stopped_early)
        .map(|c| c.cell.name.as_str())
        .collect();
    assert_eq!(cached, promoted, "grid must resume exactly the promoted cells");
    let third = campaign::run(rt, &spec, &store).unwrap();
    assert!(third.all_cached(), "deepened entries must still serve every rung");

    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------------
// Result-store lifecycle: gc never evicts the campaign being resumed.
// ---------------------------------------------------------------------------

#[test]
fn gc_never_evicts_entries_of_the_resumed_campaign() {
    let (store, dir) = tmp_store("gc_resume");
    let rt = Runtime::shared("artifacts").unwrap();
    let spec = two_by_two(2);

    let first = campaign::run(rt.clone(), &spec, &store).unwrap();
    assert!(first.failed().is_empty(), "{:?}", first.failure_lines());

    // Unrelated junk entries share the store.
    let mut junk_keys = Vec::new();
    for seed in 100..104u64 {
        let mut job = tiny_base();
        job.seed = seed;
        job.name = format!("junk{seed}");
        let key = campaign::cell_key(&job);
        let report = first.cells[0].report.clone().unwrap();
        store
            .commit(
                &key,
                campaign::CellOutcome::new(&job, &report)
                    .cell(&job.name)
                    .campaign("camp"),
            )
            .unwrap();
        junk_keys.push(key);
    }

    // The hardest eviction policy there is (`keep_last 0`), protecting the
    // campaign about to be resumed — exactly what
    // `flsim campaign gc --keep-last 0 --spec <spec>` does.
    let protect: std::collections::BTreeSet<String> = campaign::expand(&spec)
        .unwrap()
        .into_iter()
        .map(|c| c.key)
        .collect();
    let opts = campaign::GcOptions {
        max_age: None,
        keep_last: Some(0),
        ..campaign::GcOptions::default()
    };
    let stats = store.gc(&opts, &protect).unwrap();
    assert_eq!(stats.scanned, 8);
    assert_eq!(stats.evicted, 4, "all junk, nothing else");
    assert_eq!(stats.kept, 4);
    for k in &junk_keys {
        assert!(!store.contains(k));
    }

    // The resumed campaign is untouched: all cache hits, zero executions.
    let execs_before = rt.stats().executions;
    let resumed = campaign::run(rt.clone(), &spec, &store).unwrap();
    assert!(resumed.all_cached(), "gc evicted a protected campaign entry");
    assert_eq!(rt.stats().executions, execs_before);

    std::fs::remove_dir_all(&dir).unwrap();
}
