//! The parallel round engine's determinism contract (the acceptance
//! criterion of the zero-copy/parallelism PR): `parallelism: N` must produce
//! bitwise-identical model hashes, byte counts and metric series to
//! `parallelism: 1` on every flow — same seed + same reduction order ⇒ same
//! bytes at any worker count.

use flsim::config::job::JobConfig;
use flsim::controller::sync::FaultPlan;
use flsim::metrics::report::RunReport;
use flsim::orchestrator::{JobState, Orchestrator, RunOptions};
use flsim::runtime::pjrt::Runtime;
use flsim::topology::TopologyKind;

fn run_at(parallelism: usize, base: &JobConfig) -> RunReport {
    let mut job = base.clone();
    job.parallelism = parallelism;
    let rt = Runtime::shared("artifacts").unwrap();
    Orchestrator::new(rt).run(&job, RunOptions::default()).unwrap()
}

fn assert_bitwise_equal(a: &RunReport, b: &RunReport, label: &str) {
    assert_eq!(a.rounds.len(), b.rounds.len(), "{label}: round counts differ");
    for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(
            ra.model_hash, rb.model_hash,
            "{label}: round {} model hash differs",
            ra.round
        );
        assert_eq!(
            ra.net_bytes, rb.net_bytes,
            "{label}: round {} net_bytes differ",
            ra.round
        );
        assert_eq!(
            ra.test_accuracy.to_bits(),
            rb.test_accuracy.to_bits(),
            "{label}: round {} accuracy differs",
            ra.round
        );
        assert_eq!(
            ra.train_loss.to_bits(),
            rb.train_loss.to_bits(),
            "{label}: round {} train loss differs",
            ra.round
        );
    }
}

fn quickstart_mini() -> JobConfig {
    let mut job = JobConfig::default_cnn("fedavg");
    job.name = "quickstart_mini".into();
    job.rounds = 3;
    job.dataset.n = 1200;
    job
}

#[test]
fn parallel_equals_sequential_on_the_quickstart_job() {
    let base = quickstart_mini();
    let seq = run_at(1, &base);
    for par in [2usize, 4, 8] {
        let p = run_at(par, &base);
        assert_bitwise_equal(&seq, &p, &format!("quickstart parallelism {par}"));
    }
    // Auto parallelism (0 = per-core) obeys the same contract.
    let auto = run_at(0, &base);
    assert_bitwise_equal(&seq, &auto, "quickstart parallelism auto");
}

#[test]
fn parallel_equals_sequential_for_stateful_strategies() {
    // SCAFFOLD moves broadcast state + per-client control variates; MOON
    // carries per-client previous-round anchors — both exercise the
    // cross-round client state the worker pool must not scramble.
    for strategy in ["scaffold", "moon", "fedprox", "dpfl"] {
        let mut base = JobConfig::default_cnn(strategy);
        base.rounds = 2;
        base.dataset.n = 600;
        let seq = run_at(1, &base);
        let par = run_at(4, &base);
        assert_bitwise_equal(&seq, &par, strategy);
    }
}

#[test]
fn parallel_equals_sequential_on_hierarchical_flow() {
    let mut base = quickstart_mini();
    base.rounds = 2;
    base.topology = TopologyKind::Hierarchical;
    base.n_workers = 3;
    let seq = run_at(1, &base);
    let par = run_at(4, &base);
    assert_bitwise_equal(&seq, &par, "hierarchical");
}

#[test]
fn parallel_equals_sequential_on_decentralized_flow() {
    let mut base = JobConfig::default_cnn("fedstellar");
    base.rounds = 2;
    base.dataset.n = 600;
    base.n_clients = 5;
    let seq = run_at(1, &base);
    let par = run_at(4, &base);
    assert_bitwise_equal(&seq, &par, "decentralized");
}

#[test]
fn parallel_equals_sequential_under_sampling_and_faults() {
    let mut base = quickstart_mini();
    base.rounds = 3;
    base.client_fraction = 0.5;
    let faults = || {
        FaultPlan::none()
            .drop_in_round("client_2", 2)
            .crash_from("client_7", 3)
    };
    let rt = Runtime::shared("artifacts").unwrap();
    let mut j1 = base.clone();
    j1.parallelism = 1;
    let seq = Orchestrator::new(rt.clone())
        .run(&j1, RunOptions::default().faults(faults()))
        .unwrap();
    let mut j4 = base.clone();
    j4.parallelism = 4;
    let par = Orchestrator::new(rt)
        .run(&j4, RunOptions::default().faults(faults()))
        .unwrap();
    assert_bitwise_equal(&seq, &par, "sampling+faults");
}

#[test]
fn parallel_equals_sequential_across_hw_profiles() {
    use flsim::aggregate::mean::ReductionOrder;
    for order in ReductionOrder::ALL {
        let mut base = quickstart_mini();
        base.rounds = 2;
        base.n_clients = 7; // odd count tickles reduction-order tree shapes
        base.hw_profile = order;
        let seq = run_at(1, &base);
        let par = run_at(4, &base);
        assert_bitwise_equal(&seq, &par, order.profile_name());
    }
}

#[test]
fn broker_memory_stays_bounded_across_a_long_run() {
    // Drive the standard flow round-by-round through the public JobState
    // API, truncating like the orchestrator does, and require the broker to
    // hold at most one round's working set at all times.
    let rt = Runtime::shared("artifacts").unwrap();
    let mut job = quickstart_mini();
    job.rounds = 12;
    job.parallelism = 2;
    let mut state = JobState::scaffold(rt, &job, FaultPlan::none()).unwrap();
    let mut peak_msgs = 0usize;
    let mut peak_bytes = 0u64;
    for round in 1..=job.rounds {
        let _ = flsim::orchestrator::run_standard_round(&mut state, round).unwrap();
        state.kv.truncate_before(round);
        peak_msgs = peak_msgs.max(state.kv.message_count());
        peak_bytes = peak_bytes.max(state.kv.retained_bytes());
        // No dead topics survive truncation.
        assert!(
            state.kv.topic_count() <= 4,
            "round {round}: {} topics live",
            state.kv.topic_count()
        );
    }
    // One round's working set: global broadcast + n client uploads + votes.
    let param_bytes = 64 + 4 * state.backend.param_count as u64;
    let bound = (job.n_clients as u64 + 2) * param_bytes + 4096;
    assert!(
        peak_bytes <= bound,
        "broker retained {peak_bytes} bytes (bound {bound})"
    );
    assert!(peak_msgs <= 2 * job.n_clients + 4);
}
