//! Cross-device scale smokes (fig12-style): virtual populations at 100k and
//! 1M clients must run rounds in O(model + sampled cohort) server memory,
//! not O(fleet). The ceilings here are deliberately loose multiples of the
//! expected footprint — they exist to catch an accidental return to
//! per-client residency (which costs GiB at these fleet sizes), not to pin
//! allocator behavior.

use flsim::config::job::{JobConfig, PopulationMode};
use flsim::metrics::resources;
use flsim::orchestrator::{Orchestrator, RunOptions};
use flsim::runtime::pjrt::Runtime;

fn scale_job(n_clients: usize, cohort: usize) -> JobConfig {
    let mut job = JobConfig::scale_logreg(n_clients);
    job.name = format!("scale_{n_clients}");
    job.population = PopulationMode::Virtual;
    job.dataset.n = 2_000;
    job.rounds = 1;
    job.client_fraction = (cohort as f64 / n_clients as f64).min(1.0);
    job
}

#[test]
fn hundred_k_clients_run_in_bounded_memory() {
    let rt = Runtime::shared("artifacts").unwrap();
    let job = scale_job(100_000, 16);
    let before = resources::rss_bytes();
    let report = Orchestrator::new(rt).run(&job, RunOptions::default()).unwrap();
    let delta = resources::rss_bytes().saturating_sub(before);

    assert_eq!(report.n_clients, 100_000);
    assert_eq!(report.rounds.len(), 1);
    assert!(report.rounds[0].net_bytes > 0, "traffic must still be metered");
    // Expected residency: rank tables (~0.8 MB), the 2k-example dataset
    // (~6 MB), one logreg model (~31 KB) and a 16-client cohort. 256 MiB
    // leaves an order of magnitude of slack while staying far below what
    // 100k resident clients would cost.
    let ceiling = 256u64 << 20;
    assert!(
        delta < ceiling,
        "100k-client round grew RSS by {delta} bytes (ceiling {ceiling}) — \
         server memory is no longer O(model + cohort)"
    );
    // The probe itself must be live on this platform, or the ceiling above
    // is vacuous.
    assert!(resources::rss_bytes() > 1 << 20, "rss probe returned ~0");
}

#[test]
fn one_million_clients_smoke() {
    let rt = Runtime::shared("artifacts").unwrap();
    let job = scale_job(1_000_000, 16);
    let before = resources::rss_bytes();
    let report = Orchestrator::new(rt).run(&job, RunOptions::default()).unwrap();
    let delta = resources::rss_bytes().saturating_sub(before);

    assert_eq!(report.n_clients, 1_000_000);
    assert_eq!(report.rounds.len(), 1);
    assert_eq!(report.rounds[0].model_hash.len(), 16);
    // 1M ranks cost ~8 MB of tables plus one transient shuffle vector in
    // the sampler; the same 256 MiB ceiling still holds with a wide margin.
    let ceiling = 256u64 << 20;
    assert!(
        delta < ceiling,
        "1M-client round grew RSS by {delta} bytes (ceiling {ceiling})"
    );
}

/// Same virtual job twice — the scale path must stay bitwise reproducible
/// (the determinism contract does not loosen with fleet size).
#[test]
fn scale_run_is_reproducible() {
    let rt = Runtime::shared("artifacts").unwrap();
    let job = scale_job(100_000, 8);
    let a = Orchestrator::new(rt.clone()).run(&job, RunOptions::default()).unwrap();
    let b = Orchestrator::new(rt).run(&job, RunOptions::default()).unwrap();
    for (x, y) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(x.model_hash, y.model_hash);
        assert_eq!(x.net_bytes, y.net_bytes);
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits());
    }
}
