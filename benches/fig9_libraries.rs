//! Bench: regenerate paper Fig 9 (RQ2 — backend/"library" comparison:
//! accuracy, wall time, memory growth, bandwidth).

use flsim::experiments::fig9;
use flsim::runtime::pjrt::Runtime;

fn main() {
    flsim::util::logging::init_from_env();
    // Measurement context: bypass the figure result cache (fresh wall clocks).
    std::env::set_var("FLSIM_REFRESH", "1");
    let rt = Runtime::shared("artifacts").expect("run `make artifacts` first");
    let reports = fig9::run(rt).expect("fig9 experiment failed");

    let get = |name: &str| reports.iter().find(|r| r.label == name).unwrap();
    let torch = get("pytorch-analog");
    let tf = get("tensorflow-analog");
    let sk = get("sklearn-analog");

    // Paper shapes: torch best accuracy & fastest; sklearn lowest accuracy
    // (different architecture) & highest bandwidth; tf slowest.
    for (what, ok) in [
        (
            "cnn ('torch') highest accuracy",
            torch.final_accuracy() >= tf.final_accuracy()
                && torch.final_accuracy() >= sk.final_accuracy(),
        ),
        (
            "mlp ('sklearn') highest bandwidth",
            sk.total_net_bytes() > torch.total_net_bytes()
                && sk.total_net_bytes() > tf.total_net_bytes(),
        ),
        (
            "cnn_v2 ('tensorflow') slowest",
            tf.total_wall_secs() >= torch.total_wall_secs(),
        ),
    ] {
        println!("shape: {what}: {}", if ok { "OK" } else { "MISS" });
    }
}
