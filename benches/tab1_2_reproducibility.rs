//! Bench: regenerate paper Tables 1-2 (RQ6 — reproducibility): accuracy and
//! loss at rounds 1-10 for 4 hardware profiles x 3 trials. Verifies the
//! tables' property programmatically: identical trials per profile, bounded
//! cross-profile drift.

use flsim::experiments::tables12;
use flsim::runtime::pjrt::Runtime;

fn main() {
    flsim::util::logging::init_from_env();
    let rt = Runtime::shared("artifacts").expect("run `make artifacts` first");
    let reports = tables12::run(rt).expect("tables12 experiment failed");
    // run() already verifies; double-check the invariant here so the bench
    // fails loudly if reproducibility regresses.
    tables12::verify_reproducibility(&reports).expect("reproducibility violated");
    println!("shape: Tables 1-2 reproducibility: OK");
}
