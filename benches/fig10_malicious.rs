//! Bench: regenerate paper Fig 10 (RQ3 — malicious workers vs majority-hash
//! consensus; honest >50% nullifies poisoning, 1:1 fluctuates).

use flsim::experiments::fig10;
use flsim::runtime::pjrt::Runtime;

fn main() {
    flsim::util::logging::init_from_env();
    let rt = Runtime::shared("artifacts").expect("run `make artifacts` first");
    let reports = fig10::run(rt).expect("fig10 experiment failed");

    let get = |name: &str| reports.iter().find(|r| r.label == name).unwrap();
    let destroyed = get("1M-0H");
    let tie = get("1M-1H");
    let h2 = get("1M-2H");
    let h3 = get("1M-3H");

    for (what, ok) in [
        (
            "1M-0H training destroyed (accuracy ~ chance)",
            destroyed.final_accuracy() < 0.25,
        ),
        (
            "honest majority (1M-2H) nullifies poisoning",
            h2.final_accuracy() > destroyed.final_accuracy() + 0.2,
        ),
        (
            "1M-3H matches 1M-2H (both clean)",
            (h3.final_accuracy() - h2.final_accuracy()).abs() < 0.15,
        ),
        (
            "1M-1H fluctuates (worse than honest-majority)",
            tie.final_accuracy() < h2.final_accuracy(),
        ),
    ] {
        println!("shape: {what}: {}", if ok { "OK" } else { "MISS" });
    }
}
