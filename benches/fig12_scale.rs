//! Bench: regenerate paper Fig 12 (RQ7 — scalability): MNIST logreg at
//! 100/250/500/1000 clients. Accuracy flat across scales; bandwidth and
//! time grow with client count.

use flsim::experiments::fig12;
use flsim::runtime::pjrt::Runtime;

fn main() {
    flsim::util::logging::init_from_env();
    let rt = Runtime::shared("artifacts").expect("run `make artifacts` first");
    let reports = fig12::run(rt).expect("fig12 experiment failed");

    let accs: Vec<f64> = reports.iter().map(|r| r.final_accuracy()).collect();
    let bytes: Vec<u64> = reports.iter().map(|r| r.total_net_bytes()).collect();
    let spread = accs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
        - accs.iter().cloned().fold(f64::INFINITY, f64::min);

    for (what, ok) in [
        ("accuracy flat across client counts (spread < 0.05)", spread < 0.05),
        (
            "bandwidth grows monotonically with clients",
            bytes.windows(2).all(|w| w[0] < w[1]),
        ),
    ] {
        println!("shape: {what}: {}", if ok { "OK" } else { "MISS" });
    }
}
