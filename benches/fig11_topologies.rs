//! Bench: regenerate paper Fig 11 (RQ5 — client-server vs hierarchical vs
//! decentralized topologies).

use flsim::experiments::fig11;
use flsim::runtime::pjrt::Runtime;

fn main() {
    flsim::util::logging::init_from_env();
    // Measurement context: bypass the figure result cache (fresh wall clocks).
    std::env::set_var("FLSIM_REFRESH", "1");
    let rt = Runtime::shared("artifacts").expect("run `make artifacts` first");
    let reports = fig11::run(rt).expect("fig11 experiment failed");

    let get = |name: &str| reports.iter().find(|r| r.label == name).unwrap();
    let cs = get("client_server");
    let hier = get("hierarchical");
    let dec = get("decentralized");

    for (what, ok) in [
        (
            "all three topologies reach similar accuracy (±0.15)",
            (cs.final_accuracy() - hier.final_accuracy()).abs() < 0.15
                && (cs.final_accuracy() - dec.final_accuracy()).abs() < 0.15,
        ),
        (
            "decentralized uses the most bandwidth",
            dec.total_net_bytes() > cs.total_net_bytes()
                && dec.total_net_bytes() > hier.total_net_bytes(),
        ),
        (
            "hierarchical costs more bandwidth than client-server",
            hier.total_net_bytes() > cs.total_net_bytes(),
        ),
    ] {
        println!("shape: {what}: {}", if ok { "OK" } else { "MISS" });
    }
}
