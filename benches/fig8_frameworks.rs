//! Bench: regenerate paper Fig 8 (RQ1 — seven FL techniques compared on
//! accuracy / loss / time / CPU+memory / bandwidth).
//!
//! Full paper setting by default (30 rounds, 5000 examples); set
//! FLSIM_ROUNDS / FLSIM_DATASET_N for a quick pass.

use flsim::experiments::fig8;
use flsim::runtime::pjrt::Runtime;

fn main() {
    flsim::util::logging::init_from_env();
    // This is a measurement context: re-execute every campaign cell instead
    // of serving stored wall clocks from the figure result cache.
    std::env::set_var("FLSIM_REFRESH", "1");
    let rt = Runtime::shared("artifacts").expect("run `make artifacts` first");
    let reports = fig8::run(rt).expect("fig8 experiment failed");

    // Shape assertions from the paper (soft-checked; prints verdicts).
    let get = |name: &str| reports.iter().find(|r| r.label == name).unwrap();
    let fedavg = get("fedavg");
    let moon = get("moon");
    let scaffold = get("scaffold");
    let flhc = get("flhc");
    let fedstellar = get("fedstellar");

    let mut verdicts = Vec::new();
    verdicts.push((
        "MOON or SCAFFOLD reach top-2 accuracy",
        top2(&reports, &[moon.label.clone(), scaffold.label.clone()]),
    ));
    verdicts.push((
        "Fedstellar uses the most bandwidth",
        fedstellar.total_net_bytes()
            == reports.iter().map(|r| r.total_net_bytes()).max().unwrap(),
    ));
    verdicts.push((
        "FL+HC is slower than FedAvg",
        flhc.total_wall_secs() > fedavg.total_wall_secs(),
    ));
    for (what, ok) in verdicts {
        println!("shape: {what}: {}", if ok { "OK" } else { "MISS" });
    }
}

fn top2(reports: &[flsim::metrics::report::RunReport], names: &[String]) -> bool {
    let mut accs: Vec<(String, f64)> = reports
        .iter()
        .map(|r| (r.label.clone(), r.final_accuracy()))
        .collect();
    accs.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    accs.iter().take(2).any(|(n, _)| names.contains(n))
}
