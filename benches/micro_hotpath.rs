//! Micro-benchmarks of the L3 hot paths (EXPERIMENTS.md §Perf): train-step
//! execution, aggregation reduction orders (sequential and block-parallel),
//! parameter hashing, KV-store publish/fetch, consensus decision, eval —
//! plus round-engine throughput at parallelism 1/4/8.
//!
//! Emits `BENCH_micro.json` (ns/op per hot path + rounds/sec per
//! parallelism level) so the perf trajectory is tracked per PR. The
//! pure-Rust sections always run; the engine-backed sections degrade to a
//! skip message if the runtime cannot be opened.

use flsim::aggregate::mean::{weighted_mean_plan, AggPlan, ReductionOrder};
use flsim::bench::{bench, BenchSuite};
use flsim::config::job::{JobConfig, PopulationMode};
use flsim::consensus::{by_name, Proposal};
use flsim::kvstore::store::{KvStore, Payload};
use flsim::metrics::resources;
use flsim::orchestrator::{Orchestrator, RunOptions};
use flsim::runtime::backend::ModelBackend;
use flsim::runtime::pjrt::Runtime;
use flsim::util::hash;
use flsim::util::rng::Rng;

fn main() {
    flsim::util::logging::init_from_env();
    let mut suite = BenchSuite::new();

    // --- L3 pure-Rust hot paths -----------------------------------------
    let dim = 72_986; // cnn-class backend size
    let mut rng = Rng::seed_from(1);
    let models: Vec<Vec<f32>> = (0..10)
        .map(|_| (0..dim).map(|_| rng.normal_f32()).collect())
        .collect();
    let refs: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
    let weights = vec![1.0f64; refs.len()];

    // cnn-class dim caps out at 4 aggregation workers (chunk threshold), so
    // bench p1/p4 here and p8 on a fig12-scale vector below where 8 workers
    // genuinely engage.
    for order in ReductionOrder::ALL {
        for par in [1usize, 4] {
            let plan = AggPlan::new(order, par);
            let r = bench(
                &format!("aggregate/10x{dim}/{order:?}/p{par}"),
                3,
                20,
                || {
                    let out = weighted_mean_plan(&refs, &weights, plan).unwrap();
                    std::hint::black_box(out);
                },
            );
            suite.push(&r);
        }
    }
    {
        let big_dim = 262_155; // fig12-scale parameter vector
        let mut brng = Rng::seed_from(2);
        let big: Vec<Vec<f32>> = (0..10)
            .map(|_| (0..big_dim).map(|_| brng.normal_f32()).collect())
            .collect();
        let brefs: Vec<&[f32]> = big.iter().map(|m| m.as_slice()).collect();
        let bweights = vec![1.0f64; brefs.len()];
        for par in [1usize, 4, 8] {
            let plan = AggPlan::new(ReductionOrder::Sequential, par);
            let r = bench(&format!("aggregate/10x{big_dim}/Sequential/p{par}"), 2, 10, || {
                let out = weighted_mean_plan(&brefs, &bweights, plan).unwrap();
                std::hint::black_box(out);
            });
            suite.push(&r);
        }
    }

    // SIMD-blocked inner kernels (aggregate::kernel): the fixed-width
    // blocked loops every reduction order now runs on. Tracked ns/op so a
    // codegen regression (lost vectorization) shows up as a gate failure.
    {
        use flsim::aggregate::kernel::{axpy, kahan_axpy, scale};
        use flsim::aggregate::mean::StreamingMean;
        let x = &models[0];
        let mut out = vec![0f32; dim];
        let r = bench(&format!("agg_kernel/axpy/{dim}"), 3, 50, || {
            axpy(&mut out, 0.1, x);
            std::hint::black_box(&out);
        });
        suite.push(&r);
        let r = bench(&format!("agg_kernel/scale/{dim}"), 3, 50, || {
            scale(&mut out, 0.1, x);
            std::hint::black_box(&out);
        });
        suite.push(&r);
        let mut comp = vec![0f32; dim];
        let r = bench(&format!("agg_kernel/kahan_axpy/{dim}"), 3, 50, || {
            kahan_axpy(&mut out, &mut comp, 0.1, x);
            std::hint::black_box(&out);
        });
        suite.push(&r);
        let r = bench(&format!("agg_kernel/streaming_push/10x{dim}"), 3, 20, || {
            let mut sm =
                StreamingMean::new(dim, refs.len() as f64, ReductionOrder::PairwiseTree).unwrap();
            for m in &refs {
                sm.push(m, 1.0).unwrap();
            }
            std::hint::black_box(sm.finish().unwrap());
        });
        suite.push(&r);
    }

    // Round-buffer arena: steady-state store() (pool hit, copy into a
    // recycled buffer) vs the pass-through alloc path — plus the reuse
    // fraction over the bench itself, printed for the log.
    {
        use flsim::kvstore::arena::RoundArena;
        let src = &models[0];
        let arena = RoundArena::new();
        let r = bench(&format!("arena/store_pooled/{dim}"), 3, 50, || {
            std::hint::black_box(arena.store(src));
        });
        suite.push(&r);
        let off = RoundArena::disabled();
        let r = bench(&format!("arena/store_alloc/{dim}"), 3, 50, || {
            std::hint::black_box(off.store(src));
        });
        suite.push(&r);
        let s = arena.stats();
        println!(
            "arena reuse: {} reused / {} allocated ({:.1}% pool hits)",
            s.reused,
            s.allocated,
            100.0 * s.reused as f64 / (s.reused + s.allocated).max(1) as f64
        );
        assert!(s.reused > 0, "arena never recycled a buffer in the bench loop");
    }

    let r = bench("hash_params/72986", 3, 20, || {
        std::hint::black_box(hash::hash_params(&models[0]));
    });
    suite.push(&r);

    // Ablation: communication-efficient compressors (bytes + error + cost).
    {
        use flsim::aggregate::compress::{compression_error, quantize, top_k, CompressedUpdate};
        let delta = &models[0];
        let dense_bytes = CompressedUpdate::Dense(delta.clone()).wire_bytes();
        for k_frac in [0.01, 0.1] {
            let k = (dim as f64 * k_frac) as usize;
            let c = top_k(delta, k);
            println!(
                "ablation compress/top_k({k_frac})       bytes {:>9} ({:>5.1}% of dense) err {:.3}",
                c.wire_bytes(),
                100.0 * c.wire_bytes() as f64 / dense_bytes as f64,
                compression_error(delta, &c)
            );
            let r = bench(&format!("compress/top_k/{k_frac}"), 2, 10, || {
                std::hint::black_box(top_k(delta, k));
            });
            suite.push(&r);
        }
        for bits in [8u8, 4, 2] {
            let c = quantize(delta, bits, &mut Rng::seed_from(5)).unwrap();
            println!(
                "ablation compress/quant{bits}          bytes {:>9} ({:>5.1}% of dense) err {:.3}",
                c.wire_bytes(),
                100.0 * c.wire_bytes() as f64 / dense_bytes as f64,
                compression_error(delta, &c)
            );
        }
    }

    // Zero-copy publish/fetch: payload construction pays one Arc conversion,
    // every broker hop afterwards is a refcount bump.
    let shared: std::sync::Arc<[f32]> = models[0].clone().into();
    let r = bench("kvstore/publish+fetch 292KiB (arc)", 3, 50, || {
        let kv = KvStore::new();
        kv.publish("t", "c0", 1, Payload::Params(shared.clone()));
        let m = kv.fetch_latest("t", "w0").unwrap();
        std::hint::black_box(m);
    });
    suite.push(&r);

    let proposals: Vec<Proposal> = (0..4)
        .map(|i| Proposal::new(format!("w{i}"), models[i % 2].clone()))
        .collect();
    let consensus = by_name("majority_hash").unwrap();
    let r = bench("consensus/majority_hash/4 workers", 3, 50, || {
        let d = consensus
            .decide(&proposals, &mut Rng::seed_from(7))
            .unwrap();
        std::hint::black_box(d);
    });
    suite.push(&r);

    // --- Engine-backed hot paths (gated: skip cleanly if unavailable) ----
    match Runtime::shared("artifacts") {
        Ok(rt) => {
            let backend = ModelBackend::new(rt.clone(), "cnn").unwrap();
            let params = backend.init(0).unwrap();
            let plit = backend.params_lit(&params).unwrap();
            let bs = backend.train_batch;
            let f: usize = backend.input_shape.iter().product();
            let mut drng = Rng::seed_from(3);
            let x: Vec<f32> = (0..bs * f).map(|_| drng.normal_f32()).collect();
            let y: Vec<i32> = (0..bs).map(|_| drng.below(10) as i32).collect();
            let (xl, yl) = backend.batch_lits(&x, &y).unwrap();

            let r = bench("engine/cnn_sgd_step/batch64", 3, 20, || {
                let out = backend.sgd(&plit, &xl, &yl, 0.01).unwrap();
                std::hint::black_box(out);
            });
            suite.push(&r);

            let eb = backend.eval_batch;
            let xe: Vec<f32> = (0..eb * f).map(|_| drng.normal_f32()).collect();
            let ye: Vec<i32> = (0..eb).map(|_| drng.below(10) as i32).collect();
            let mask = vec![1.0f32; eb];
            let (xel, yel, ml) = backend.eval_lits(&xe, &ye, &mask).unwrap();
            let r = bench("engine/cnn_eval/batch256", 3, 20, || {
                let out = backend.eval_batch(&plit, &xel, &yel, &ml).unwrap();
                std::hint::black_box(out);
            });
            suite.push(&r);

            // Round-engine throughput at parallelism 1/4/8 on a mini job.
            // Same seed at every level — the per-round model hashes must
            // agree bitwise while the wall clock drops, and the *virtual*
            // makespan (sim_round_secs) must not move at all.
            let mut golden_hash: Option<String> = None;
            let mut golden_sim: Option<f64> = None;
            for par in [1usize, 4, 8] {
                let mut job = JobConfig::default_cnn("fedavg");
                job.name = format!("bench_round_p{par}");
                job.rounds = 2;
                job.dataset.n = 1200;
                job.n_clients = 8;
                job.parallelism = par;
                let orch = Orchestrator::new(rt.clone());
                let t0 = std::time::Instant::now();
                let report = orch.run(&job, RunOptions::default()).unwrap();
                let secs = t0.elapsed().as_secs_f64();
                let rounds_per_sec = job.rounds as f64 / secs;
                let h = report.rounds.last().unwrap().model_hash.clone();
                match &golden_hash {
                    None => golden_hash = Some(h),
                    Some(g) => assert_eq!(
                        g, &h,
                        "parallelism {par} changed the model hash — determinism broken"
                    ),
                }
                let sim = report.total_sim_round_secs();
                match golden_sim {
                    None => golden_sim = Some(sim),
                    Some(g) => assert_eq!(
                        g.to_bits(),
                        sim.to_bits(),
                        "parallelism {par} changed the virtual makespan"
                    ),
                }
                println!(
                    "round_throughput parallelism={par}: {rounds_per_sec:.3} rounds/s ({secs:.2}s, sim {sim:.2}s)"
                );
                suite.push_throughput(&format!("round/parallelism={par}"), rounds_per_sec);
                suite.push_makespan(&format!("round/parallelism={par}"), sim);
            }

            // Virtual-clock makespan per topology at equal model size and
            // rounds (the Fig 11e transfer-time ordering, as a tracked
            // series: fully_connected > hierarchical > client_server).
            let topo_jobs: Vec<(&str, JobConfig)> = vec![
                ("client_server", {
                    let mut j = JobConfig::default_cnn("fedavg");
                    j.name = "bench_topo_cs".into();
                    j
                }),
                ("hierarchical", {
                    let mut j = JobConfig::default_cnn("fedavg");
                    j.name = "bench_topo_hier".into();
                    j.topology = flsim::topology::TopologyKind::Hierarchical;
                    j.n_workers = 3;
                    j
                }),
                ("fully_connected", {
                    let mut j = JobConfig::default_cnn("fedstellar");
                    j.name = "bench_topo_mesh".into();
                    j
                }),
            ];
            for (name, mut job) in topo_jobs {
                job.rounds = 1;
                job.dataset.n = 600;
                job.n_clients = 6;
                let orch = Orchestrator::new(rt.clone());
                let report = orch.run(&job, RunOptions::default()).unwrap();
                let sim = report.total_sim_round_secs();
                let net = report.total_sim_net_secs();
                println!("topology_makespan {name}: sim_round {sim:.3}s, sim_net {net:.3}s");
                suite.push_makespan(&format!("topology/{name}"), sim);
            }

            // Cross-device scale (fig12-style, virtual population): one
            // round at N ∈ {1k, 10k, 100k, 1M} clients with a ~16-client
            // sampled cohort. Tracks wall clock per round plus the process
            // peak RSS after each run — the `mem_peak_bytes` series the
            // regression gate treats as higher-is-worse. (The hard memory
            // ceilings are asserted in rust/tests/scale_virtual.rs; here
            // the trajectory is recorded per PR.)
            for &n in &[1_000usize, 10_000, 100_000, 1_000_000] {
                let mut job = JobConfig::scale_logreg(n);
                job.name = format!("bench_scale_{n}");
                job.population = PopulationMode::Virtual;
                job.dataset.n = 2_000;
                job.rounds = 1;
                job.client_fraction = (16.0 / n as f64).min(1.0);
                let orch = Orchestrator::new(rt.clone());
                let t0 = std::time::Instant::now();
                let report = orch.run(&job, RunOptions::default()).unwrap();
                let secs = t0.elapsed().as_secs_f64();
                assert_eq!(report.rounds.len(), 1, "scale n={n} run incomplete");
                let peak = resources::peak_rss_bytes();
                println!(
                    "scale n={n}: {secs:.3}s/round, peak rss {:.1} MiB",
                    peak as f64 / (1024.0 * 1024.0)
                );
                suite.push_throughput(&format!("scale/rounds_per_sec/n={n}"), 1.0 / secs);
                suite.push_memory(&format!("scale/n={n}"), peak);
            }

            let stats = rt.stats();
            println!(
                "runtime[{}]: compiles={} executions={} compile={:.2}s execute={:.2}s",
                rt.engine_name(),
                stats.compiles,
                stats.executions,
                stats.compile_secs,
                stats.execute_secs
            );
            // cnn train/eval/init + logreg train/eval/init (the scale
            // sweep's backend) — anything beyond that is a cache miss.
            assert!(
                stats.compiles <= 6,
                "executable cache miss: {} compiles",
                stats.compiles
            );
        }
        Err(e) => {
            println!("skipping engine-backed benches: {e}");
        }
    }

    suite.write("BENCH_micro.json").expect("writing BENCH_micro.json");
    println!("wrote BENCH_micro.json ({} results)", suite.results.len());
}
