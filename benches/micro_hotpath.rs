//! Micro-benchmarks of the L3 hot paths (EXPERIMENTS.md §Perf): train-step
//! execution, aggregation reduction orders, parameter hashing, KV-store
//! publish/fetch, consensus decision, eval — plus executable-cache checks.

use flsim::aggregate::mean::{weighted_mean, ReductionOrder};
use flsim::bench::bench;
use flsim::consensus::{by_name, Proposal};
use flsim::kvstore::store::{KvStore, Payload};
use flsim::runtime::backend::ModelBackend;
use flsim::runtime::pjrt::Runtime;
use flsim::util::hash;
use flsim::util::rng::Rng;

fn main() {
    flsim::util::logging::init_from_env();
    let rt = Runtime::shared("artifacts").expect("run `make artifacts` first");

    // --- L3 pure-Rust hot paths -----------------------------------------
    let dim = 72_986; // cnn backend size
    let mut rng = Rng::seed_from(1);
    let models: Vec<Vec<f32>> = (0..10)
        .map(|_| (0..dim).map(|_| rng.normal_f32()).collect())
        .collect();
    let refs: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
    let weights = vec![1.0f64; refs.len()];

    for order in ReductionOrder::ALL {
        bench(
            &format!("aggregate/10x{dim}/{:?}", order),
            3,
            20,
            || {
                let out = weighted_mean(&refs, &weights, order).unwrap();
                std::hint::black_box(out);
            },
        );
    }

    bench("hash_params/72986", 3, 20, || {
        std::hint::black_box(hash::hash_params(&models[0]));
    });

    // Ablation: communication-efficient compressors (bytes + error + cost).
    {
        use flsim::aggregate::compress::{compression_error, quantize, top_k, CompressedUpdate};
        let delta = &models[0];
        let dense_bytes = CompressedUpdate::Dense(delta.clone()).wire_bytes();
        for k_frac in [0.01, 0.1] {
            let k = (dim as f64 * k_frac) as usize;
            let c = top_k(delta, k);
            println!(
                "ablation compress/top_k({k_frac})       bytes {:>9} ({:>5.1}% of dense) err {:.3}",
                c.wire_bytes(),
                100.0 * c.wire_bytes() as f64 / dense_bytes as f64,
                compression_error(delta, &c)
            );
            bench(&format!("compress/top_k/{k_frac}"), 2, 10, || {
                std::hint::black_box(top_k(delta, k));
            });
        }
        for bits in [8u8, 4, 2] {
            let c = quantize(delta, bits, &mut Rng::seed_from(5)).unwrap();
            println!(
                "ablation compress/quant{bits}          bytes {:>9} ({:>5.1}% of dense) err {:.3}",
                c.wire_bytes(),
                100.0 * c.wire_bytes() as f64 / dense_bytes as f64,
                compression_error(delta, &c)
            );
        }
    }

    bench("kvstore/publish+fetch 292KiB", 3, 50, || {
        let mut kv = KvStore::new();
        kv.publish("t", "c0", 1, Payload::Params(models[0].clone()));
        let m = kv.fetch_latest("t", "w0").unwrap();
        std::hint::black_box(m);
    });

    let proposals: Vec<Proposal> = (0..4)
        .map(|i| Proposal::new(format!("w{i}"), models[i % 2].clone()))
        .collect();
    let consensus = by_name("majority_hash").unwrap();
    bench("consensus/majority_hash/4 workers", 3, 50, || {
        let d = consensus
            .decide(&proposals, &mut Rng::seed_from(7))
            .unwrap();
        std::hint::black_box(d);
    });

    // --- PJRT execution hot paths ----------------------------------------
    let backend = ModelBackend::new(rt.clone(), "cnn").unwrap();
    let params = backend.init(0).unwrap();
    let plit = backend.params_lit(&params).unwrap();
    let bs = backend.train_batch;
    let f: usize = backend.input_shape.iter().product();
    let mut drng = Rng::seed_from(3);
    let x: Vec<f32> = (0..bs * f).map(|_| drng.normal_f32()).collect();
    let y: Vec<i32> = (0..bs).map(|_| drng.below(10) as i32).collect();
    let (xl, yl) = backend.batch_lits(&x, &y).unwrap();

    bench("pjrt/cnn_sgd_step/batch64", 3, 20, || {
        let out = backend.sgd(&plit, &xl, &yl, 0.01).unwrap();
        std::hint::black_box(out);
    });

    let eb = backend.eval_batch;
    let xe: Vec<f32> = (0..eb * f).map(|_| drng.normal_f32()).collect();
    let ye: Vec<i32> = (0..eb).map(|_| drng.below(10) as i32).collect();
    let mask = vec![1.0f32; eb];
    let (xel, yel, ml) = backend.eval_lits(&xe, &ye, &mask).unwrap();
    bench("pjrt/cnn_eval/batch256", 3, 20, || {
        let out = backend.eval_batch(&plit, &xel, &yel, &ml).unwrap();
        std::hint::black_box(out);
    });

    // Executable-cache effectiveness: every artifact compiles exactly once.
    let stats = rt.stats();
    println!(
        "runtime: compiles={} executions={} compile={:.2}s execute={:.2}s",
        stats.compiles, stats.executions, stats.compile_secs, stats.execute_secs
    );
    assert!(
        stats.compiles <= 3,
        "executable cache miss: {} compiles",
        stats.compiles
    );
    println!("shape: executable cache hit rate after warmup: OK");
}
