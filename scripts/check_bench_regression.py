#!/usr/bin/env python3
"""Compare a fresh BENCH_micro.json against the committed BENCH_baseline.json.

Usage:
    scripts/check_bench_regression.py BASELINE CURRENT [--tolerance 0.30]
    scripts/check_bench_regression.py --write-baseline BASELINE CURRENT

Every `results[].ns_per_op` series present in *both* files is compared; a
current value more than ``tolerance`` (default +/-30%, override with
``--tolerance`` or the FLSIM_BENCH_TOLERANCE env var) above its baseline is
a regression and fails the check. Values more than ``tolerance`` *below*
baseline are reported as improvements with a hint to refresh the baseline
(stale baselines hide future regressions). Series present in only one file
are listed informationally (new/retired benches are not failures).

A baseline marked ``"provisional": true`` downgrades regressions to
warnings and always exits 0: commit the BENCH_micro.json artifact of a real
CI run (via ``--write-baseline``, which drops the flag) to arm the gate.

Only the Python standard library is used.
"""

import argparse
import json
import os
import sys


def load(path):
    with open(path, "r", encoding="utf-8") as f:
        doc = json.load(f)
    if doc.get("schema") != "flsim-bench-v1":
        sys.exit(f"{path}: unexpected schema {doc.get('schema')!r}")
    return doc


def index_ns_per_op(doc):
    return {r["name"]: float(r["ns_per_op"]) for r in doc.get("results", [])}


def write_baseline(current_path, baseline_path):
    doc = load(current_path)
    doc.pop("provisional", None)
    doc.pop("note", None)
    with open(baseline_path, "w", encoding="utf-8") as f:
        json.dump(doc, f, separators=(",", ":"))
        f.write("\n")
    print(f"wrote {baseline_path} from {current_path} ({len(doc.get('results', []))} series)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=float(os.environ.get("FLSIM_BENCH_TOLERANCE", "0.30")),
        help="allowed fractional drift per series (default 0.30 = +/-30%%)",
    )
    ap.add_argument(
        "--write-baseline",
        action="store_true",
        help="promote CURRENT (arg 2) to BASELINE (arg 1) instead of comparing",
    )
    args = ap.parse_args()

    if args.write_baseline:
        write_baseline(args.current, args.baseline)
        return

    base_doc = load(args.baseline)
    cur_doc = load(args.current)
    provisional = bool(base_doc.get("provisional"))
    base = index_ns_per_op(base_doc)
    cur = index_ns_per_op(cur_doc)

    shared = sorted(set(base) & set(cur))
    only_base = sorted(set(base) - set(cur))
    only_cur = sorted(set(cur) - set(base))

    regressions, improvements = [], []
    for name in shared:
        b, c = base[name], cur[name]
        if b <= 0.0:
            continue
        ratio = c / b
        line = f"{name}: {b:.1f} -> {c:.1f} ns/op ({ratio - 1.0:+.0%} vs baseline)"
        if ratio > 1.0 + args.tolerance:
            regressions.append(line)
        elif ratio < 1.0 - args.tolerance:
            improvements.append(line)

    print(
        f"bench-regression: {len(shared)} series compared "
        f"(tolerance +/-{args.tolerance:.0%}), "
        f"{len(regressions)} regressed, {len(improvements)} improved"
    )
    for line in improvements:
        print(f"  IMPROVED  {line}  — consider refreshing BENCH_baseline.json")
    for line in regressions:
        print(f"  REGRESSED {line}")
    for name in only_cur:
        print(f"  NEW       {name} ({cur[name]:.1f} ns/op) — not in baseline")
    for name in only_base:
        print(f"  RETIRED   {name} — in baseline but not in current run")

    if provisional:
        if not shared:
            print(
                "baseline is provisional and empty: promote a real CI run's "
                "BENCH_micro.json artifact with --write-baseline to arm the gate"
            )
        elif regressions:
            print("baseline is provisional: regressions reported as warnings only")
        return

    if regressions:
        sys.exit(f"{len(regressions)} benchmark series regressed beyond the threshold")


if __name__ == "__main__":
    main()
