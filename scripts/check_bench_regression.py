#!/usr/bin/env python3
"""Compare a fresh BENCH_micro.json against the committed BENCH_baseline.json.

Usage:
    scripts/check_bench_regression.py BASELINE CURRENT
        [--tolerance 0.30] [--thresholds SPEC]
    scripts/check_bench_regression.py --write-baseline BASELINE CURRENT

Three series kinds are compared, each with its own regression direction and
default tolerance:

    kind           field            worse when   default tolerance
    results        ns_per_op        higher       0.30  (host-speed noise)
    throughput     ops_per_sec      lower        0.30  (host-speed noise)
    makespan       sim_round_secs   higher       0.01  (virtual clock —
                                                        deterministic, so
                                                        any drift is real)
    memory         mem_peak_bytes   higher       0.30  (allocator/kernel
                                                        noise on VmHWM)

The base ``--tolerance`` (or the FLSIM_BENCH_TOLERANCE env var) replaces the
0.30 default of the wall-clock kinds; ``--thresholds`` refines per kind or
per series name:

    --thresholds "makespan=0.02,throughput=0.40,name:round/par*=0.50"

Items are comma-separated ``kind=FRACTION`` (kind: ns_per_op/results,
ops_per_sec/throughput, sim_round_secs/makespan) or ``name:PATTERN=FRACTION``
(fnmatch pattern against the series name; first matching pattern wins and
beats any kind-level setting).

Series present in only one file are listed informationally (new/retired
benches are not failures). A baseline marked ``"provisional": true``
downgrades regressions to warnings and always exits 0: commit the
BENCH_micro.json artifact of a real CI run (via ``--write-baseline``, which
drops the flag) to arm the gate.

Only the Python standard library is used.
"""

import argparse
import fnmatch
import json
import os
import sys

# kind -> (json list key, value field, +1 = higher is worse / -1 = lower is
# worse, default tolerance)
SERIES_KINDS = {
    "ns_per_op": ("results", "ns_per_op", +1, 0.30),
    "ops_per_sec": ("throughput", "ops_per_sec", -1, 0.30),
    "sim_round_secs": ("makespan", "sim_round_secs", +1, 0.01),
    "mem_peak_bytes": ("memory", "mem_peak_bytes", +1, 0.30),
}

# Accepted aliases for kind-level threshold overrides.
KIND_ALIASES = {
    "results": "ns_per_op",
    "ns_per_op": "ns_per_op",
    "throughput": "ops_per_sec",
    "ops_per_sec": "ops_per_sec",
    "makespan": "sim_round_secs",
    "sim_round_secs": "sim_round_secs",
    "memory": "mem_peak_bytes",
    "mem_peak_bytes": "mem_peak_bytes",
}


class ThresholdSpecError(ValueError):
    """A malformed --thresholds spec."""


def parse_thresholds(spec):
    """Parse a --thresholds spec into (kind_overrides, pattern_overrides).

    ``kind_overrides`` maps canonical kind -> tolerance; ``pattern_overrides``
    is an ordered list of (fnmatch pattern, tolerance). Raises
    ThresholdSpecError on malformed input.
    """
    kinds, patterns = {}, []
    if not spec:
        return kinds, patterns
    for item in spec.split(","):
        item = item.strip()
        if not item:
            continue
        if "=" not in item:
            raise ThresholdSpecError(f"threshold item {item!r}: expected KEY=FRACTION")
        key, _, raw = item.partition("=")
        key = key.strip()
        try:
            tol = float(raw.strip())
        except ValueError:
            raise ThresholdSpecError(f"threshold item {item!r}: bad fraction {raw.strip()!r}")
        if tol < 0:
            raise ThresholdSpecError(f"threshold item {item!r}: tolerance must be >= 0")
        if key.startswith("name:"):
            pattern = key[len("name:"):].strip()
            if not pattern:
                raise ThresholdSpecError(f"threshold item {item!r}: empty name pattern")
            patterns.append((pattern, tol))
        elif key in KIND_ALIASES:
            kinds[KIND_ALIASES[key]] = tol
        else:
            raise ThresholdSpecError(
                f"threshold item {item!r}: unknown kind {key!r} "
                f"(use {sorted(set(KIND_ALIASES))} or name:PATTERN)"
            )
    return kinds, patterns


def tolerance_for(name, kind, base_tolerance, kind_overrides, pattern_overrides):
    """Resolve one series' tolerance: name pattern > kind override > default.

    ``base_tolerance`` (the --tolerance flag), when given, replaces the
    built-in default of the wall-clock kinds only — the makespan series is
    a deterministic virtual clock and keeps its tight default unless
    explicitly overridden.
    """
    for pattern, tol in pattern_overrides:
        if fnmatch.fnmatch(name, pattern):
            return tol
    if kind in kind_overrides:
        return kind_overrides[kind]
    default = SERIES_KINDS[kind][3]
    if base_tolerance is not None and kind != "sim_round_secs":
        return base_tolerance
    return default


def load(path):
    with open(path, "r", encoding="utf-8") as f:
        doc = json.load(f)
    if doc.get("schema") != "flsim-bench-v1":
        sys.exit(f"{path}: unexpected schema {doc.get('schema')!r}")
    return doc


def index_series(doc, kind):
    list_key, field, _, _ = SERIES_KINDS[kind]
    out = {}
    for r in doc.get(list_key, []):
        out[r["name"]] = float(r[field])
    return out


def classify(kind, base, cur, tol):
    """Return 'regressed' / 'improved' / 'ok' for one series pair."""
    if base <= 0.0:
        return "ok"
    direction = SERIES_KINDS[kind][2]
    ratio = cur / base
    worse = ratio > 1.0 + tol if direction > 0 else ratio < 1.0 - tol
    better = ratio < 1.0 - tol if direction > 0 else ratio > 1.0 + tol
    if worse:
        return "regressed"
    if better:
        return "improved"
    return "ok"


def write_baseline(current_path, baseline_path):
    doc = load(current_path)
    doc.pop("provisional", None)
    doc.pop("note", None)
    with open(baseline_path, "w", encoding="utf-8") as f:
        json.dump(doc, f, separators=(",", ":"))
        f.write("\n")
    n = sum(len(doc.get(k, [])) for k, _, _, _ in SERIES_KINDS.values())
    print(f"wrote {baseline_path} from {current_path} ({n} series)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("current")
    env_tol = os.environ.get("FLSIM_BENCH_TOLERANCE")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=float(env_tol) if env_tol is not None else None,
        help="base tolerance for the wall-clock kinds (default 0.30); the "
        "makespan kind keeps its own default unless set via --thresholds",
    )
    ap.add_argument(
        "--thresholds",
        default=os.environ.get("FLSIM_BENCH_THRESHOLDS", ""),
        help='per-kind/per-name tolerances, e.g. "makespan=0.02,name:agg/*=0.5"',
    )
    ap.add_argument(
        "--write-baseline",
        action="store_true",
        help="promote CURRENT (arg 2) to BASELINE (arg 1) instead of comparing",
    )
    args = ap.parse_args()

    if args.write_baseline:
        write_baseline(args.current, args.baseline)
        return

    try:
        kind_overrides, pattern_overrides = parse_thresholds(args.thresholds)
    except ThresholdSpecError as e:
        sys.exit(f"--thresholds: {e}")

    base_doc = load(args.baseline)
    cur_doc = load(args.current)
    provisional = bool(base_doc.get("provisional"))

    compared = 0
    regressions, improvements = [], []
    for kind, (list_key, _, direction, _) in SERIES_KINDS.items():
        base = index_series(base_doc, kind)
        cur = index_series(cur_doc, kind)
        unit = kind.replace("_", " ")
        for name in sorted(set(base) & set(cur)):
            b, c = base[name], cur[name]
            if b <= 0.0:
                continue
            compared += 1
            tol = tolerance_for(name, kind, args.tolerance, kind_overrides, pattern_overrides)
            verdict = classify(kind, b, c, tol)
            drift = c / b - 1.0
            line = (
                f"{list_key}/{name}: {b:.4g} -> {c:.4g} {unit} "
                f"({drift:+.1%} vs baseline, tolerance +/-{tol:.0%})"
            )
            if verdict == "regressed":
                regressions.append(line)
            elif verdict == "improved":
                improvements.append(line)
        for name in sorted(set(cur) - set(base)):
            print(f"  NEW       {list_key}/{name} ({cur[name]:.4g} {unit}) — not in baseline")
        for name in sorted(set(base) - set(cur)):
            print(f"  RETIRED   {list_key}/{name} — in baseline but not in current run")

    print(
        f"bench-regression: {compared} series compared, "
        f"{len(regressions)} regressed, {len(improvements)} improved"
    )
    for line in improvements:
        print(f"  IMPROVED  {line}  — consider refreshing BENCH_baseline.json")
    for line in regressions:
        print(f"  REGRESSED {line}")

    if provisional:
        if compared == 0:
            print(
                "baseline is provisional and empty: promote a real CI run's "
                "BENCH_micro.json artifact with --write-baseline to arm the gate"
            )
        elif regressions:
            print("baseline is provisional: regressions reported as warnings only")
        return

    if regressions:
        sys.exit(f"{len(regressions)} benchmark series regressed beyond the threshold")


if __name__ == "__main__":
    main()
