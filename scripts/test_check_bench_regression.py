#!/usr/bin/env python3
"""Unit tests for the bench-regression gate (threshold parser + series
comparison semantics). Stdlib-only; run directly or via the CI step:

    python3 scripts/test_check_bench_regression.py
"""

import json
import os
import subprocess
import sys
import tempfile
import unittest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import check_bench_regression as gate  # noqa: E402


class ParseThresholdsTest(unittest.TestCase):
    def test_empty_spec_is_no_overrides(self):
        self.assertEqual(gate.parse_thresholds(""), ({}, []))
        self.assertEqual(gate.parse_thresholds(None), ({}, []))

    def test_kind_overrides_accept_aliases(self):
        kinds, patterns = gate.parse_thresholds(
            "makespan=0.02, throughput=0.4,ns_per_op=0.25,memory=0.1"
        )
        self.assertEqual(
            kinds,
            {
                "sim_round_secs": 0.02,
                "ops_per_sec": 0.4,
                "ns_per_op": 0.25,
                "mem_peak_bytes": 0.1,
            },
        )
        self.assertEqual(patterns, [])
        # Field-name aliases resolve to the same canonical kinds.
        kinds2, _ = gate.parse_thresholds(
            "sim_round_secs=0.02,ops_per_sec=0.4,results=0.25,mem_peak_bytes=0.1"
        )
        self.assertEqual(kinds, kinds2)

    def test_name_patterns_keep_order(self):
        _, patterns = gate.parse_thresholds(
            "name:round/par*=0.5,name:agg/?=0.1"
        )
        self.assertEqual(patterns, [("round/par*", 0.5), ("agg/?", 0.1)])

    def test_trailing_commas_and_spaces_are_fine(self):
        kinds, patterns = gate.parse_thresholds(" makespan=0.05 , ")
        self.assertEqual(kinds, {"sim_round_secs": 0.05})
        self.assertEqual(patterns, [])

    def test_malformed_items_raise(self):
        for bad in [
            "makespan",                # no '='
            "makespan=fast",           # not a number
            "makespan=-0.1",           # negative
            "wallclock=0.3",           # unknown kind
            "name:=0.3",               # empty pattern
        ]:
            with self.assertRaises(gate.ThresholdSpecError, msg=bad):
                gate.parse_thresholds(bad)


class ToleranceResolutionTest(unittest.TestCase):
    def test_defaults_per_kind(self):
        self.assertEqual(gate.tolerance_for("x", "ns_per_op", None, {}, []), 0.30)
        self.assertEqual(gate.tolerance_for("x", "ops_per_sec", None, {}, []), 0.30)
        self.assertEqual(gate.tolerance_for("x", "sim_round_secs", None, {}, []), 0.01)
        self.assertEqual(gate.tolerance_for("x", "mem_peak_bytes", None, {}, []), 0.30)

    def test_base_tolerance_replaces_wall_clock_defaults_only(self):
        self.assertEqual(gate.tolerance_for("x", "ns_per_op", 0.5, {}, []), 0.5)
        self.assertEqual(gate.tolerance_for("x", "ops_per_sec", 0.5, {}, []), 0.5)
        # The virtual clock is deterministic: host-speed slack must not
        # loosen it implicitly.
        self.assertEqual(gate.tolerance_for("x", "sim_round_secs", 0.5, {}, []), 0.01)

    def test_precedence_name_over_kind_over_default(self):
        kinds = {"ns_per_op": 0.2}
        patterns = [("agg/*", 0.05), ("agg/pairwise", 0.9)]
        # First matching pattern wins.
        self.assertEqual(
            gate.tolerance_for("agg/pairwise", "ns_per_op", None, kinds, patterns), 0.05
        )
        self.assertEqual(
            gate.tolerance_for("kv/publish", "ns_per_op", None, kinds, patterns), 0.2
        )
        self.assertEqual(
            gate.tolerance_for("kv/publish", "ops_per_sec", None, kinds, patterns), 0.30
        )


class ClassifyTest(unittest.TestCase):
    def test_higher_is_worse_kinds(self):
        self.assertEqual(gate.classify("ns_per_op", 100.0, 140.0, 0.30), "regressed")
        self.assertEqual(gate.classify("ns_per_op", 100.0, 120.0, 0.30), "ok")
        self.assertEqual(gate.classify("ns_per_op", 100.0, 60.0, 0.30), "improved")
        self.assertEqual(gate.classify("sim_round_secs", 10.0, 10.2, 0.01), "regressed")
        self.assertEqual(gate.classify("sim_round_secs", 10.0, 10.05, 0.01), "ok")
        # Peak memory: more bytes = worse.
        self.assertEqual(gate.classify("mem_peak_bytes", 1e8, 1.5e8, 0.30), "regressed")
        self.assertEqual(gate.classify("mem_peak_bytes", 1e8, 1.2e8, 0.30), "ok")
        self.assertEqual(gate.classify("mem_peak_bytes", 1e8, 0.5e8, 0.30), "improved")

    def test_lower_is_worse_for_throughput(self):
        self.assertEqual(gate.classify("ops_per_sec", 50.0, 30.0, 0.30), "regressed")
        self.assertEqual(gate.classify("ops_per_sec", 50.0, 45.0, 0.30), "ok")
        self.assertEqual(gate.classify("ops_per_sec", 50.0, 70.0, 0.30), "improved")

    def test_zero_baseline_never_classifies(self):
        self.assertEqual(gate.classify("ns_per_op", 0.0, 99.0, 0.30), "ok")


class EndToEndTest(unittest.TestCase):
    """Run the script as CI does and check its exit codes."""

    SCRIPT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "check_bench_regression.py")

    @staticmethod
    def doc(ns=100.0, ops=50.0, mk=10.0, mem=None, provisional=False):
        d = {
            "schema": "flsim-bench-v1",
            "results": [{"name": "agg/mean", "ns_per_op": ns, "iters": 5}],
            "throughput": [{"name": "round/p4", "ops_per_sec": ops}],
            "makespan": [{"name": "topo/cs", "sim_round_secs": mk}],
        }
        if mem is not None:
            d["memory"] = [{"name": "scale/n=100000", "mem_peak_bytes": mem}]
        if provisional:
            d["provisional"] = True
        return d

    def run_gate(self, baseline, current, *extra):
        with tempfile.TemporaryDirectory() as td:
            bp = os.path.join(td, "base.json")
            cp = os.path.join(td, "cur.json")
            with open(bp, "w", encoding="utf-8") as f:
                json.dump(baseline, f)
            with open(cp, "w", encoding="utf-8") as f:
                json.dump(current, f)
            proc = subprocess.run(
                [sys.executable, self.SCRIPT, bp, cp, *extra],
                capture_output=True,
                text=True,
            )
            return proc.returncode, proc.stdout + proc.stderr

    def test_within_tolerance_passes(self):
        code, out = self.run_gate(self.doc(), self.doc(ns=110.0, ops=45.0, mk=10.05))
        self.assertEqual(code, 0, out)
        self.assertIn("3 series compared", out)

    def test_makespan_is_tight_by_default(self):
        # +5% makespan is a regression even though the wall-clock kinds
        # would tolerate it.
        code, out = self.run_gate(self.doc(), self.doc(mk=10.5))
        self.assertNotEqual(code, 0, out)
        self.assertIn("REGRESSED", out)
        self.assertIn("makespan", out)

    def test_throughput_drop_fails_and_thresholds_can_loosen(self):
        code, out = self.run_gate(self.doc(), self.doc(ops=30.0))
        self.assertNotEqual(code, 0, out)
        code, out = self.run_gate(
            self.doc(), self.doc(ops=30.0), "--thresholds", "throughput=0.5"
        )
        self.assertEqual(code, 0, out)

    def test_memory_growth_fails_and_is_gated_higher_is_worse(self):
        code, out = self.run_gate(self.doc(mem=1.0e8), self.doc(mem=1.5e8))
        self.assertNotEqual(code, 0, out)
        self.assertIn("REGRESSED", out)
        self.assertIn("memory/scale/n=100000", out)
        # Shrinking the peak is an improvement, not a failure.
        code, out = self.run_gate(self.doc(mem=1.0e8), self.doc(mem=0.5e8))
        self.assertEqual(code, 0, out)
        self.assertIn("IMPROVED", out)

    def test_memory_series_new_in_current_is_informational(self):
        # A baseline predating the memory series must not fail the gate —
        # new series report as NEW until the baseline is refreshed.
        code, out = self.run_gate(self.doc(), self.doc(mem=1.0e8))
        self.assertEqual(code, 0, out)
        self.assertIn("NEW", out)
        self.assertIn("memory/scale/n=100000", out)

    def test_provisional_baseline_warns_only(self):
        code, out = self.run_gate(
            self.doc(provisional=True), self.doc(ns=500.0, ops=1.0, mk=99.0)
        )
        self.assertEqual(code, 0, out)
        self.assertIn("provisional", out)

    def test_bad_thresholds_spec_fails_fast(self):
        code, out = self.run_gate(self.doc(), self.doc(), "--thresholds", "nope=0.3")
        self.assertNotEqual(code, 0, out)
        self.assertIn("unknown kind", out)


if __name__ == "__main__":
    unittest.main(verbosity=2)
